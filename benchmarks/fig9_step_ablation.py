"""Figure 9 analogue: contribution of scheduler step 1 vs step 1+2.

Step 1 = coarse fusion only (cache_size=∞ disables splitting);
step 2 adds cost-model splitting.  Paper: step 1 gives the bulk (6.7× over
sequential), step 2 helps 90% of matrices further.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.tilefusion import api

from .util import bench_n, bench_suite, gmean, time_fn

N = 2048
# step 1 only = cache_size=∞ disables splitting; step 1+2 adds the cost
# model.  Both are just cache-budget knobs on the unified API.
S1 = api.FusionSpec(p=8, cache_size=1e12, ct_size=512, uniform_split=False)
S12 = api.FusionSpec(p=8, cache_size=150_000.0, ct_size=512,
                     uniform_split=False)


def run():
    rows = []
    rng = np.random.default_rng(3)
    bcol = 64
    sp2 = []
    n = bench_n(N)
    for name, a in bench_suite(N).items():
        b = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((bcol, bcol)), jnp.float32)
        s1 = api.get_schedule(a, b_col=bcol, c_col=bcol, spec=S1).sched
        s12 = api.get_schedule(a, b_col=bcol, c_col=bcol, spec=S12).sched
        t1 = time_fn(api.tile_fused_matmul, a, b, c, backend="xla", spec=S1)
        t12 = time_fn(api.tile_fused_matmul, a, b, c, backend="xla", spec=S12)
        sp2.append(t1 / t12)
        rows.append((f"fig9/{name}/step1", t1,
                     f"step12_us={t12:.0f};step2_speedup={t1/t12:.2f};"
                     f"tiles_s1={len(s1.wavefronts[0])};"
                     f"tiles_s12={len(s12.wavefronts[0])}"))
    rows.append(("fig9/GMEAN", 0.0, f"step2_speedup={gmean(sp2):.2f}"))
    return rows
