"""Serving-tier benchmark: hit rate and request latency on a drifting
sampled-subgraph stream, plus the incremental-vs-full inspection micro.

Two headline numbers (both threshold-checked via benchmarks/thresholds.json):

* ``serving/stream/*`` — a request stream where every pattern is distinct
  or near-distinct (the case that defeats the content-keyed cache, paper
  §4.2.3's amortization assumption).  Reports per-request latency (p50 as
  the us column, p99 derived) and the tier hit rate: the fraction of
  requests served without a full Algorithm-1 inspection.
* ``serving/incremental/*`` — patching a resident schedule for a ≤5%-dirty
  pattern vs re-running the full inspector; the speedup is the reason the
  incremental path exists.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks import util
from repro.core.sparse.random import (induced_subgraph, perturb_rows,
                                      powerlaw_graph)
from repro.core.tilefusion import api, build_schedule, to_device_schedule
from repro.core.tilefusion.serving import ServingTier, incremental_update
from repro.core.tilefusion.schedule import pad_device_schedule

KNOBS = dict(p=8, cache_size=600_000.0, ct_size=256)


def _stream_row(n_sub: int, requests: int, jump_p: float, seed: int = 0):
    """Drive the drifting stream through a fresh tier; one CSV row."""
    rng = np.random.default_rng(seed)
    base = powerlaw_graph(8 * n_sub, avg_deg=6, seed=seed)
    windows = [induced_subgraph(base, s, n_sub)
               for s in (0, n_sub, 3 * n_sub)]
    feat = 16
    tier = ServingTier(b_col=feat, c_col=feat, **KNOBS)
    b = rng.standard_normal((n_sub, feat))
    c = rng.standard_normal((feat, feat))
    current = windows[0]
    lat = []
    for i in range(requests):
        r = rng.random()
        if r < jump_p and i:
            current = windows[int(rng.integers(len(windows)))]
        elif r < jump_p + 0.3:
            k = max(1, current.n_rows // 50)   # ~2% re-sampled rows
            current = perturb_rows(
                current, rng.choice(current.n_rows, k, replace=False),
                seed=int(rng.integers(1 << 31)))
        t0 = time.perf_counter()
        d = tier.matmul(current, b, c)
        d.block_until_ready()
        lat.append(time.perf_counter() - t0)
    lat_us = np.asarray(lat) * 1e6
    st = tier.stats
    derived = (f"p99_us={float(np.percentile(lat_us, 99)):.1f};"
               f"hit_rate={tier.hit_rate():.3f};"
               f"exact={st['exact_hits']};incremental={st['incremental']};"
               f"rebuilds={st['rebuilds']};requests={st['requests']}")
    return (f"serving/stream/n{n_sub}", float(np.median(lat_us)), derived)


def _incremental_row(n: int, seed: int = 0):
    """Patch-vs-full-inspection micro at 5% dirty rows; one CSV row."""
    rng = np.random.default_rng(seed)
    a = powerlaw_graph(n, avg_deg=8, seed=seed)
    entry = api.get_schedule(a, b_col=16, c_col=16,
                             spec=api.FusionSpec(uniform_split=True, **KNOBS))
    k = max(1, n // 20)
    slack = k + 8
    ds = pad_device_schedule(entry.dsched, j1_slots=slack,
                             spill_slots=slack * 16)
    entry = dataclasses.replace(entry, dsched=ds)
    dirty = np.sort(rng.choice(n, k, replace=False))
    a_new = perturb_rows(a, dirty, seed=seed + 1)
    patched = incremental_update(a, entry, a_new, dirty,
                                 cache_size=KNOBS["cache_size"])
    assert patched is not None, "incremental path bailed in the micro-bench"
    incr_us = util.time_fn(
        lambda: incremental_update(a, entry, a_new, dirty,
                                   cache_size=KNOBS["cache_size"]))

    def full():
        sched = build_schedule(a_new, b_col=16, c_col=16,
                               uniform_split=True, **KNOBS)
        return to_device_schedule(a_new, sched,
                                  width_cap=entry.width_cap)

    full_us = util.time_fn(full)
    derived = (f"full_us={full_us:.1f};speedup={full_us / incr_us:.1f}x;"
               f"dirty_rows={k}")
    return (f"serving/incremental/n{n}", incr_us, derived)


def run():
    api.clear_schedule_cache()
    rows = []
    if util.smoke():
        # no window jumps: 1 rebuild in 12 requests keeps hit_rate >= 0.9
        rows.append(_stream_row(n_sub=192, requests=12, jump_p=0.0))
        rows.append(_incremental_row(util.bench_n(2048)))
    else:
        rows.append(_stream_row(n_sub=2048, requests=96, jump_p=0.04))
        rows.append(_stream_row(n_sub=1024, requests=48, jump_p=0.04))
        rows.append(_incremental_row(2048))
    return rows
