"""Sharded tile-fusion driver: the wavefront-0 grid over a device mesh.

On this CPU container the "mesh" is whatever the host platform exposes
(force more with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` —
the CI multi-device leg does); a 1-device platform exercises the
trivial-mesh fallback, so the driver never bit-rots regardless of the
environment.  Timings on forced host devices are NOT accelerator
performance — the derived columns that matter are the partition balance and
the halo-vs-replication byte ratio from ``cost_model.shard_comm_model``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import api, fused_ref

from .util import bench_n, time_fn


def _mesh() -> Mesh:
    return Mesh(np.array(jax.devices()), ("shards",))


def run():
    rows = []
    rng = np.random.default_rng(11)
    mesh = _mesh()
    n_dev = len(jax.devices())
    bcol = 32
    n = bench_n(4096)
    knobs = dict(p=8, cache_size=100_000.0, ct_size=256)
    mats = {"banded_spd_b8": banded_spd(n, 8, seed=11),
            "powerlaw_d4": powerlaw_graph(n, 4, seed=11)}
    for name, a in mats.items():
        b = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((bcol, bcol)), jnp.float32)
        want = fused_ref.unfused_gemm_spmm(a, np.asarray(b, np.float64),
                                           np.asarray(c, np.float64))
        for backend, kw in (("xla", {}), ("sharded", {"mesh": mesh})):
            t_us = time_fn(api.tile_fused_matmul, a, b, c,
                           backend=backend, **kw, **knobs)
            got = api.tile_fused_matmul(a, b, c, backend=backend, **kw,
                                        **knobs)
            err = float(np.abs(np.asarray(got) - want).max())
            derived = f"devices={n_dev};max_err={err:.2e}"
            if backend == "sharded":
                entry = api.get_schedule(a, b_col=bcol, c_col=bcol,
                                         mesh=mesh, **knobs)
                if entry.shard is not None:
                    cm = entry.shard.comm_model
                    counts = entry.shard.shard_tile_counts()
                    derived += (f";halo_rows={cm['halo_rows']}"
                                f";halo_frac={cm['halo_fraction']:.3f}"
                                f";tiles_per_shard="
                                f"{int(counts.min())}-{int(counts.max())}")
                else:
                    derived += ";trivial_mesh_fallback"
            rows.append((f"sharded/gemm_spmm/{name}/{backend}", t_us,
                         derived))
        # SpMM-SpMM on the powerlaw pattern only (op-1 == A, paper setting)
        if name != "powerlaw_d4":
            continue
        cs = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        want2 = fused_ref.unfused_spmm_spmm(a, a, np.asarray(cs, np.float64))
        for backend, kw in (("xla", {}), ("sharded", {"mesh": mesh})):
            t_us = time_fn(api.tile_fused_matmul, a, a, cs,
                           backend=backend, **kw, **knobs)
            got = api.tile_fused_matmul(a, a, cs, backend=backend, **kw,
                                        **knobs)
            err = float(np.abs(np.asarray(got) - want2).max())
            rows.append((f"sharded/spmm_spmm/{name}/{backend}", t_us,
                         f"devices={n_dev};max_err={err:.2e}"))
    return rows
