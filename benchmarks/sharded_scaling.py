"""Sharded tile-fusion driver: the wavefront-0 grid over a device mesh.

On this CPU container the "mesh" is whatever the host platform exposes
(force more with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` —
the CI multi-device leg does); a 1-device platform exercises the
trivial-mesh fallback, so the driver never bit-rots regardless of the
environment.  Timings on forced host devices are NOT accelerator
performance — the derived columns that matter are the partition balance
and the modeled byte counts from ``cost_model.shard_comm_model``: the
halo-vs-replication ratio, the output-combine prices (``comb_psum`` vs
``comb_rs``), and the async-overlap pricing (``halo_eff`` /
``crit_bytes``).  Every sharded cell is timed twice — halo exchange
synchronous (``t_sync_us``) and issued ahead of the wavefront-0 body
(``t_overlap_us``) — and reports the layout/overlap choice
``choose_mesh_layout``'s pricing would make on the same mesh
(``auto_layout`` / ``auto_overlap``).  On a ≥4-device platform a 2-D mesh
runs the same problem under ``shard_layout="auto"`` (the 1.5D rung); on a
≥8-device platform a 2×2×2 mesh runs the 2.5D rung (depth-replicated
wavefront-1 stacks combined over the ``z`` axis).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import api, fused_ref

from .util import bench_n, time_fn

BASE_SPEC = api.FusionSpec(p=8, cache_size=100_000.0, ct_size=256)


def _mesh() -> Mesh:
    return Mesh(np.array(jax.devices()), ("shards",))


def _mesh_2d() -> Mesh | None:
    n = len(jax.devices())
    if n < 4:
        return None
    # drop a trailing device on odd counts so the (n//2, 2) grid reshapes
    devs = jax.devices()[: (n // 2) * 2]
    return Mesh(np.array(devs).reshape(n // 2, 2), ("x", "y"))


def _mesh_3d() -> Mesh | None:
    devs = jax.devices()
    if len(devs) < 8:
        return None
    return Mesh(np.array(devs[:8]).reshape(2, 2, 2), ("x", "y", "z"))


def _shard_derived(entry) -> str:
    """Derived columns for a sharded run: partition balance + the comm
    model's priced bytes (halo, combines, overlap-effective critical
    path).  ``combine_bytes`` is the price of the combine the schedule
    actually chose (plus the 2.5D depth reduction when present) — the
    thresholds gate rides on it staying off the full-psum cost."""
    if entry.shard is None:
        return ";trivial_mesh_fallback;combine_bytes=0"
    cm = entry.shard.comm_model
    counts = entry.shard.shard_tile_counts()
    chosen = (cm["combine_bytes_reduce_scatter"]
              if entry.shard.combine == "reduce_scatter"
              else cm["combine_bytes"]) + cm["depth_combine_bytes"]
    return (f";layout={entry.shard.layout}"
            f";combine={entry.shard.combine}"
            f";overlap={int(entry.shard.overlap)}"
            f";n_depth={entry.shard.n_depth}"
            f";halo_rows={cm['halo_rows']}"
            f";halo_frac={cm['halo_fraction']:.3f}"
            f";halo_eff={cm['halo_bytes_effective']:.0f}"
            f";crit_bytes={cm['critical_bytes']:.0f}"
            f";comb_psum={cm['combine_bytes']:.0f}"
            f";comb_rs={cm['combine_bytes_reduce_scatter']:.0f}"
            f";combine_bytes={chosen:.0f}"
            f";tiles_per_shard="
            f"{int(counts.min())}-{int(counts.max())}")


def _auto_choice(a, *, bcol, mesh, b_is_sparse=False) -> str:
    """What the Eq-3 pricing picks on this mesh when left to itself."""
    spec = dataclasses.replace(BASE_SPEC, mesh=mesh, shard_layout="auto",
                               overlap="auto")
    entry = api.get_schedule(a, b_col=bcol, c_col=bcol,
                             b_is_sparse=b_is_sparse, spec=spec)
    if entry.shard is None:
        return ";auto_layout=fallback;auto_overlap=0"
    return (f";auto_layout={entry.shard.layout}"
            f";auto_overlap={int(entry.shard.overlap)}")


def run():
    rows = []
    rng = np.random.default_rng(11)
    n_dev = len(jax.devices())
    bcol = 32
    n = bench_n(4096)
    mesh_cells = [("sharded", _mesh(), "1d")]
    mesh2d = _mesh_2d()
    if mesh2d is not None:
        mesh_cells.append(("sharded2d", mesh2d, "auto"))
    mesh3d = _mesh_3d()
    if mesh3d is not None:
        mesh_cells.append(("sharded3d", mesh3d, "2.5d"))
    mats = {"banded_spd_b8": banded_spd(n, 8, seed=11),
            "powerlaw_d4": powerlaw_graph(n, 4, seed=11)}
    for name, a in mats.items():
        b = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((bcol, bcol)), jnp.float32)
        want = fused_ref.unfused_gemm_spmm(a, np.asarray(b, np.float64),
                                           np.asarray(c, np.float64))
        for backend, mesh, layout in [("xla", None, None)] + mesh_cells:
            if mesh is None:
                t_us = time_fn(api.tile_fused_matmul, a, b, c,
                               backend=backend, spec=BASE_SPEC)
                got = api.tile_fused_matmul(a, b, c, backend=backend,
                                            spec=BASE_SPEC)
                err = float(np.abs(np.asarray(got) - want).max())
                rows.append((f"sharded/gemm_spmm/{name}/{backend}", t_us,
                             f"devices={n_dev};max_err={err:.2e}"
                             ";combine_bytes=0"))
                continue
            s_off = dataclasses.replace(BASE_SPEC, mesh=mesh,
                                        shard_layout=layout, overlap=False)
            s_on = dataclasses.replace(s_off, overlap=True)
            t_off = time_fn(api.tile_fused_matmul, a, b, c,
                            backend="sharded", spec=s_off)
            t_on = time_fn(api.tile_fused_matmul, a, b, c,
                           backend="sharded", spec=s_on)
            got = api.tile_fused_matmul(a, b, c, backend="sharded", spec=s_on)
            err = float(np.abs(np.asarray(got) - want).max())
            entry = api.get_schedule(a, b_col=bcol, c_col=bcol, spec=s_on)
            rows.append((
                f"sharded/gemm_spmm/{name}/{backend}", t_off,
                f"devices={n_dev};max_err={err:.2e}"
                f";t_sync_us={t_off:.0f};t_overlap_us={t_on:.0f}"
                + _shard_derived(entry)
                + _auto_choice(a, bcol=bcol, mesh=mesh)))
        # SpMM-SpMM on the powerlaw pattern only (op-1 == A, paper setting)
        if name != "powerlaw_d4":
            continue
        cs = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        want2 = fused_ref.unfused_spmm_spmm(a, a, np.asarray(cs, np.float64))
        for backend, mesh, layout in [("xla", None, None)] + mesh_cells:
            if mesh is None:
                t_us = time_fn(api.tile_fused_matmul, a, a, cs,
                               backend=backend, spec=BASE_SPEC)
                got = api.tile_fused_matmul(a, a, cs, backend=backend,
                                            spec=BASE_SPEC)
                err = float(np.abs(np.asarray(got) - want2).max())
                rows.append((f"sharded/spmm_spmm/{name}/{backend}", t_us,
                             f"devices={n_dev};max_err={err:.2e}"
                             ";combine_bytes=0"))
                continue
            s_off = dataclasses.replace(BASE_SPEC, mesh=mesh,
                                        shard_layout=layout, overlap=False)
            s_on = dataclasses.replace(s_off, overlap=True)
            t_off = time_fn(api.tile_fused_matmul, a, a, cs,
                            backend="sharded", spec=s_off)
            t_on = time_fn(api.tile_fused_matmul, a, a, cs,
                           backend="sharded", spec=s_on)
            got = api.tile_fused_matmul(a, a, cs, backend="sharded",
                                        spec=s_on)
            err = float(np.abs(np.asarray(got) - want2).max())
            entry = api.get_schedule(a, b_col=bcol, c_col=bcol,
                                     b_is_sparse=True, spec=s_on)
            rows.append((
                f"sharded/spmm_spmm/{name}/{backend}", t_off,
                f"devices={n_dev};max_err={err:.2e}"
                f";t_sync_us={t_off:.0f};t_overlap_us={t_on:.0f}"
                + _shard_derived(entry)
                + _auto_choice(a, bcol=bcol, mesh=mesh, b_is_sparse=True)))
    return rows
