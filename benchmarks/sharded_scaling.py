"""Sharded tile-fusion driver: the wavefront-0 grid over a device mesh.

On this CPU container the "mesh" is whatever the host platform exposes
(force more with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` —
the CI multi-device leg does); a 1-device platform exercises the
trivial-mesh fallback, so the driver never bit-rots regardless of the
environment.  Timings on forced host devices are NOT accelerator
performance — the derived columns that matter are the partition balance
and the modeled byte counts from ``cost_model.shard_comm_model``: the
halo-vs-replication ratio and the output-combine prices
(``comb_psum`` vs ``comb_rs`` — the reduce-scatter row remap must be
strictly cheaper whenever more than one shard owns output rows).  On a
≥4-device platform a second sharded row runs the same problem on a 2-D
mesh under ``shard_layout="auto"`` so the 1.5D column-replica path is
exercised and priced too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import api, fused_ref

from .util import bench_n, time_fn


def _mesh() -> Mesh:
    return Mesh(np.array(jax.devices()), ("shards",))


def _mesh_2d() -> Mesh | None:
    n = len(jax.devices())
    if n < 4:
        return None
    # drop a trailing device on odd counts so the (n//2, 2) grid reshapes
    devs = jax.devices()[: (n // 2) * 2]
    return Mesh(np.array(devs).reshape(n // 2, 2), ("x", "y"))


def _shard_derived(entry) -> str:
    """Derived columns for a sharded run: partition balance + the comm
    model's priced bytes (halo, psum combine, reduce-scatter combine)."""
    if entry.shard is None:
        return ";trivial_mesh_fallback"
    cm = entry.shard.comm_model
    counts = entry.shard.shard_tile_counts()
    return (f";layout={entry.shard.layout}"
            f";combine={entry.shard.combine}"
            f";halo_rows={cm['halo_rows']}"
            f";halo_frac={cm['halo_fraction']:.3f}"
            f";comb_psum={cm['combine_bytes']:.0f}"
            f";comb_rs={cm['combine_bytes_reduce_scatter']:.0f}"
            f";tiles_per_shard="
            f"{int(counts.min())}-{int(counts.max())}")


def run():
    rows = []
    rng = np.random.default_rng(11)
    n_dev = len(jax.devices())
    bcol = 32
    n = bench_n(4096)
    knobs = dict(p=8, cache_size=100_000.0, ct_size=256)
    mesh_cells = [("sharded", _mesh(), {})]
    mesh2d = _mesh_2d()
    if mesh2d is not None:
        mesh_cells.append(("sharded2d", mesh2d, {"shard_layout": "auto"}))
    mats = {"banded_spd_b8": banded_spd(n, 8, seed=11),
            "powerlaw_d4": powerlaw_graph(n, 4, seed=11)}
    for name, a in mats.items():
        b = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((bcol, bcol)), jnp.float32)
        want = fused_ref.unfused_gemm_spmm(a, np.asarray(b, np.float64),
                                           np.asarray(c, np.float64))
        cells = [("xla", None, {})] + mesh_cells
        for backend, mesh, extra in cells:
            kw = dict(extra)
            if mesh is not None:
                kw["mesh"] = mesh
            be = "sharded" if mesh is not None else backend
            t_us = time_fn(api.tile_fused_matmul, a, b, c,
                           backend=be, **kw, **knobs)
            got = api.tile_fused_matmul(a, b, c, backend=be, **kw, **knobs)
            err = float(np.abs(np.asarray(got) - want).max())
            derived = f"devices={n_dev};max_err={err:.2e}"
            if mesh is not None:
                entry = api.get_schedule(a, b_col=bcol, c_col=bcol,
                                         **kw, **knobs)
                derived += _shard_derived(entry)
            rows.append((f"sharded/gemm_spmm/{name}/{backend}", t_us,
                         derived))
        # SpMM-SpMM on the powerlaw pattern only (op-1 == A, paper setting)
        if name != "powerlaw_d4":
            continue
        cs = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        want2 = fused_ref.unfused_spmm_spmm(a, a, np.asarray(cs, np.float64))
        for backend, mesh, extra in cells:
            kw = dict(extra)
            if mesh is not None:
                kw["mesh"] = mesh
            be = "sharded" if mesh is not None else backend
            t_us = time_fn(api.tile_fused_matmul, a, a, cs,
                           backend=be, **kw, **knobs)
            got = api.tile_fused_matmul(a, a, cs, backend=be, **kw,
                                        **knobs)
            err = float(np.abs(np.asarray(got) - want2).max())
            derived = f"devices={n_dev};max_err={err:.2e}"
            if mesh is not None:
                entry = api.get_schedule(a, b_col=bcol, c_col=bcol,
                                         b_is_sparse=True, **kw, **knobs)
                derived += _shard_derived(entry)
            rows.append((f"sharded/spmm_spmm/{name}/{backend}", t_us,
                         derived))
    return rows
