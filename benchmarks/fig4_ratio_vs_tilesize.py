"""Figure 4 analogue: fused ratio vs coarse tile size.

Paper: ratio grows with tile size, improvement rate slows after ctSize=2048
(their chosen heuristic).  The same saturation shape should appear here.
"""
from __future__ import annotations

import numpy as np

from repro.core.tilefusion import api

from .util import bench_suite, sweep


def run():
    rows = []
    suite = bench_suite(4096)
    for ct in sweep((64, 128, 256, 512, 1024, 2048, 4096), (64, 256)):
        ratios = []
        for name, a in suite.items():
            # p=1: measure the pure ratio-vs-tile-size curve (the paper's
            # Fig 4), not the scheduler's load-balance-clamped t
            sched = api.get_schedule(
                a, b_col=64, c_col=64,
                spec=api.FusionSpec(p=1, cache_size=1e12, ct_size=ct,
                                    uniform_split=False)).sched
            ratios.append(sched.fused_ratio)
        rows.append((f"fig4/fused_ratio/ct{ct}", 0.0,
                     f"mean_fused_ratio={np.mean(ratios):.3f}"))
    return rows
