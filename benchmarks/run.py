# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV and (with --json) writes a machine-readable BENCH_<date>.json for
# trend tracking; --check compares rows against benchmarks/thresholds.json
# and exits non-zero on a regression.
#
# ``--smoke`` runs every driver at one tiny problem size (sets
# REPRO_BENCH_SMOKE=1 before the drivers import; see benchmarks/util.py) —
# a bit-rot check, not a measurement.  The tier-1 suite invokes it via
# tests/test_bench_smoke.py.  Thresholds not marked ``"smoke": true`` are
# skipped under --smoke (tiny-size timings are meaningless).
from __future__ import annotations

import argparse
import json
import os
import sys
import time

MODULES = [
    "fig1_fused_ratio_census",
    "fig4_ratio_vs_tilesize",
    "table2_gemm_spmm",
    "table3_spmm_spmm",
    "fig6_fused_baselines",
    "fig9_step_ablation",
    "fig10_amortization",
    "inspector_bench",
    "reorder_ablation",
    "hetero_bench",
    "kernels_bench",
    "sharded_scaling",
    "serving_bench",
    "train_bench",
]

THRESHOLDS_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "thresholds.json")


def parse_derived(s: str) -> dict:
    """``"k=v;k2=v2"`` -> dict, floats where possible (``39.5x`` -> 39.5)."""
    out: dict = {}
    for kv in s.split(";"):
        if "=" not in kv:
            continue
        k, v = kv.split("=", 1)
        try:
            out[k] = float(v.rstrip("x"))
        except ValueError:
            out[k] = v
    return out


def emit_json(path: str, rows: list, meta: dict) -> None:
    """Write the collected rows as a trend-trackable JSON document."""
    doc = {"meta": meta,
           "rows": [{"name": n, "us": us, "derived": parse_derived(d),
                     "derived_raw": d} for n, us, d in rows]}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def check_thresholds(rows: list, thresholds: list, smoke: bool) -> list:
    """Threshold violations (empty list = pass).

    Each threshold is ``{"row": <name prefix>, "key": "us"|<derived key>,
    "min"/"max": float, "smoke": bool}``; a threshold with no matching row
    is itself a violation (a renamed bench must not silently drop its
    regression gate)."""
    violations = []
    for th in thresholds:
        if smoke and not th.get("smoke", False):
            continue
        matches = [r for r in rows if r[0].startswith(th["row"])]
        if not matches:
            violations.append(f"threshold {th['row']}: no matching rows")
            continue
        for name, us, derived in matches:
            val = us if th["key"] == "us" else parse_derived(derived).get(
                th["key"])
            if not isinstance(val, float):
                violations.append(
                    f"{name}: key {th['key']!r} missing or non-numeric")
                continue
            if "min" in th and val < th["min"]:
                violations.append(
                    f"{name}: {th['key']}={val:g} < min {th['min']:g}")
            if "max" in th and val > th["max"]:
                violations.append(
                    f"{name}: {th['key']}={val:g} > max {th['max']:g}")
    return violations


def main(argv=None) -> None:
    import importlib
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("modules", nargs="*",
                    help="run only these drivers (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes, 1 rep (bit-rot check)")
    ap.add_argument("--json", nargs="?", const="__default__", default=None,
                    metavar="PATH",
                    help="also write rows to PATH "
                         "(default BENCH_<yyyymmdd>.json)")
    ap.add_argument("--check", action="store_true",
                    help="compare rows against benchmarks/thresholds.json; "
                         "exit 1 on a regression")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    only = set(args.modules) or None
    rows: list = []
    print("name,us_per_call,derived")
    ran = []
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        t0 = time.time()
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}", flush=True)
            rows.append((name, float(us), derived))
        ran.append(mod_name)
        print(f"# {mod_name} done in {time.time()-t0:.0f}s", flush=True)
    if args.json is not None:
        path = (f"BENCH_{time.strftime('%Y%m%d')}.json"
                if args.json == "__default__" else args.json)
        emit_json(path, rows, meta={
            "date": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "smoke": bool(args.smoke), "modules": ran})
        print(f"# wrote {path}", flush=True)
    if args.check:
        with open(THRESHOLDS_PATH) as f:
            thresholds = json.load(f)
        if only:   # partial runs only gate the thresholds they can see
            thresholds = [t for t in thresholds
                          if any(r[0].startswith(t["row"]) for r in rows)]
        violations = check_thresholds(rows, thresholds, bool(args.smoke))
        for v in violations:
            print(f"THRESHOLD VIOLATION: {v}", file=sys.stderr)
        if violations:
            sys.exit(1)
        print("# thresholds ok", flush=True)


if __name__ == "__main__":
    main()
