# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# ``--smoke`` runs every driver at one tiny problem size (sets
# REPRO_BENCH_SMOKE=1 before the drivers import; see benchmarks/util.py) —
# a bit-rot check, not a measurement.  The tier-1 suite invokes it via
# tests/test_bench_smoke.py.
from __future__ import annotations

import os
import sys
import time

MODULES = [
    "fig1_fused_ratio_census",
    "fig4_ratio_vs_tilesize",
    "table2_gemm_spmm",
    "table3_spmm_spmm",
    "fig6_fused_baselines",
    "fig9_step_ablation",
    "fig10_amortization",
    "inspector_bench",
    "reorder_ablation",
    "kernels_bench",
    "sharded_scaling",
]


def main() -> None:
    import importlib
    args = sys.argv[1:]
    if "--smoke" in args:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        args = [a for a in args if a != "--smoke"]
    only = args or None
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        t0 = time.time()
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"# {mod_name} done in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
