# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time

MODULES = [
    "fig1_fused_ratio_census",
    "fig4_ratio_vs_tilesize",
    "table2_gemm_spmm",
    "table3_spmm_spmm",
    "fig6_fused_baselines",
    "fig9_step_ablation",
    "fig10_amortization",
    "reorder_ablation",
    "kernels_bench",
]


def main() -> None:
    import importlib
    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        if only and mod_name not in only:
            continue
        t0 = time.time()
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"# {mod_name} done in {time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
