"""Benchmark helpers: timing + the shared matrix suite."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, reps: int = 7, warmup: int = 2, **kw):
    """Median wall time in microseconds (paper uses median of 7 runs)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def gmean(xs):
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(np.maximum(xs, 1e-12)).mean()))
