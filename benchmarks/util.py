"""Benchmark helpers: timing, smoke-mode scaling, the shared matrix suite.

Smoke mode (``REPRO_BENCH_SMOKE=1``, set by ``benchmarks/run.py --smoke``
and the tier-1 bit-rot test) runs every driver end-to-end at one tiny
problem size with minimal repetitions — the numbers are meaningless, the
point is that the driver still executes.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np


def smoke() -> bool:
    """True when benchmarks should run one tiny problem size."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


def bench_n(full: int, smoke_n: int = 256) -> int:
    """Problem size: ``full`` normally, ``smoke_n`` under --smoke."""
    return smoke_n if smoke() else full


def sweep(full, smoke_values):
    """Parameter sweep: the full grid normally, a 1-point grid under --smoke."""
    return smoke_values if smoke() else full


def bench_suite(n: int, seed: int = 0):
    """The shared matrix suite at ``bench_n(n)``; trimmed to two matrices
    (one per paper group) under --smoke."""
    from repro.core.sparse.random import benchmark_suite
    suite = benchmark_suite(bench_n(n), seed=seed)
    if smoke():
        suite = {k: suite[k] for k in ("banded_spd_b4", "powerlaw_d4")}
    return suite


def time_fn(fn, *args, reps: int = 7, warmup: int = 2, **kw):
    """Median wall time in microseconds (paper uses median of 7 runs)."""
    if smoke():
        reps, warmup = 1, 1
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def gmean(xs):
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(np.maximum(xs, 1e-12)).mean()))
