"""Table 2 analogue: GeMM-SpMM fused vs unfused across bCol.

Paper: tile fusion vs unfused gmean 1.97× (EPYC DP bCol=128), 1.36-1.84×
across settings, driven by D1 staying in cache between the two loops.

Container caveat (EXPERIMENTS.md): graph-level XLA-CPU cannot pin D1 to
cache (it materializes the intermediate buffer regardless), so wall-clock
here does not show the paper's CPU effect.  The locality win is what the
Pallas kernel expresses on TPU; the exact HBM-traffic model from the
schedule (``traffic_saving``) is therefore reported alongside measured time
— it is the quantity the paper's speedup is made of.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.tilefusion import api

from .util import bench_n, bench_suite, gmean, sweep, time_fn

N = 2048
P = 8
CACHE = 300_000.0
SPEC = api.FusionSpec(p=P, cache_size=CACHE, ct_size=512)


def run():
    rows = []
    n = bench_n(N)
    suite = bench_suite(N)
    rng = np.random.default_rng(0)
    for bcol in sweep((32, 64, 128), (32,)):
        speedups, savings = {}, {}
        for name, a in suite.items():
            b = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
            c = jnp.asarray(rng.standard_normal((bcol, bcol)), jnp.float32)
            entry = api.get_schedule(a, b_col=bcol, c_col=bcol, spec=SPEC)
            sched = entry.sched
            t_f = time_fn(api.tile_fused_matmul, a, b, c, backend="xla",
                          spec=SPEC)
            t_u = time_fn(api.tile_fused_matmul, a, b, c, backend="unfused",
                          spec=SPEC)
            tm = entry.traffic_model
            speedups[name] = t_u / t_f
            savings[name] = tm["traffic_saving"]
            rows.append((
                f"table2/gemm_spmm/{name}/bcol{bcol}/fused", t_f,
                f"speedup={t_u/t_f:.2f};fused_ratio={sched.fused_ratio:.2f};"
                f"traffic_saving={tm['traffic_saving']:.2f};"
                f"d1_spill_rows={tm['d1_spill_rows']}"))
        rows.append((f"table2/gemm_spmm/GMEAN/bcol{bcol}", 0.0,
                     f"gmean_speedup={gmean(speedups.values()):.3f};"
                     f"mean_traffic_saving={np.mean(list(savings.values())):.3f}"))
    return rows
