"""Heterogeneous multi-relation fusion: one stacked dispatch vs the loop.

The hetero-GNN serving pattern (HGT/RGCN) issues one small SpMM per
relation; each underfills the machine and re-pays the fixed dispatch
cost (schedule lookup, operand staging, kernel launch).  The stacked
path (`hetero_fused_matmul`) runs the whole relation set as ONE dispatch
over the block-diagonal pattern.

Gated row (``hetero/fused_vs_loop`` in thresholds.json): the stacked
dispatch must beat the per-relation loop on a many-relation SpMM-SpMM
set.  Both sides are pinned to ``backend="unfused"`` so the comparison
isolates the amortization claim — ONE dispatch vs N dispatches of the
*same* executor.  SpMM-SpMM is the gated pair because its stacked op-1
is a block-diagonal CSR whose work is exactly the sum of the relation
nnz; the GeMM-SpMM stack pays a dense block-diagonal first operand
(op-1 compute inflated ~n_rel-fold — XLA cannot skip the zero blocks),
so it is reported ungated.

The ``hetero/auto/*`` rows run the same comparison through
``backend="auto"`` at a larger per-relation size — informational: they
show the stacked pattern driving the full pricing stack (Eq-3 floor,
reorder knob, executor selection) end to end.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.sparse.random import powerlaw_graph
from repro.core.tilefusion import api, hetero

from .util import bench_n, time_fn


def _relations(n_rel: int, n: int, b_col: int, c_col: int, *, seed: int,
               sparse_op1: bool = False):
    rng = np.random.default_rng(seed)
    rels = []
    for r in range(n_rel):
        a = powerlaw_graph(n, 4 + (r % 3), seed=seed + 7 * r)
        if sparse_op1:
            a1 = powerlaw_graph(n, 4, seed=seed + 101 + r)
            c = jnp.asarray(rng.standard_normal((n, c_col)), jnp.float32)
            rels.append((a, a1, c))
        else:
            b = jnp.asarray(rng.standard_normal((n, b_col)), jnp.float32)
            c = jnp.asarray(rng.standard_normal((b_col, c_col)), jnp.float32)
            rels.append((a, b, c))
    return rels


def _time_pair(rels, *, backend, spec):
    fused = lambda: hetero.hetero_fused_matmul(rels, backend=backend,
                                               spec=spec)
    loop = lambda: hetero.hetero_loop_matmul(rels, backend=backend,
                                             spec=spec)
    return time_fn(fused), time_fn(loop)


def run():
    rows = []
    spec = api.FusionSpec(p=8, cache_size=600_000.0, ct_size=512)

    # gated: many tiny relations, identical executor on both sides
    n_rel, n = 48, bench_n(64, smoke_n=48)
    rels = _relations(n_rel, n, 32, 32, seed=21, sparse_op1=True)
    t_fused, t_loop = _time_pair(rels, backend="unfused", spec=spec)
    rows.append((f"hetero/fused_vs_loop/spmm_spmm_r{n_rel}", t_fused,
                 f"speedup={t_loop / max(t_fused, 1e-9):.2f}x;"
                 f"loop_us={t_loop:.1f};n_rel={n_rel};n={n}"))

    # informational: full auto dispatch at a larger per-relation size
    n_rel, n = 6, bench_n(1024, smoke_n=128)
    for case, sparse_op1 in (("gemm_spmm", False), ("spmm_spmm", True)):
        rels = _relations(n_rel, n, 32, 32, seed=21, sparse_op1=sparse_op1)
        t_fused, t_loop = _time_pair(rels, backend="auto", spec=spec)
        st = api.schedule_cache_stats()
        rows.append((f"hetero/auto/{case}_r{n_rel}", t_fused,
                     f"speedup={t_loop / max(t_fused, 1e-9):.2f}x;"
                     f"loop_us={t_loop:.1f};n_rel={n_rel};n={n};"
                     f"reorder_entries={st['reorder_entries']}"))
    return rows
