"""Figure 1 analogue: fraction of GeMM-SpMM computation inside coarse fused
tiles (ctSize=2048) across the matrix suite.  Paper: 34% average over all
2893 SuiteSparse matrices."""
from __future__ import annotations

import numpy as np

from repro.core.tilefusion import fused_compute_ratio

from .util import bench_suite


def run():
    rows = []
    ratios = []
    for name, a in bench_suite(4096).items():
        r = fused_compute_ratio(a, ct_size=2048)
        ratios.append(r)
        rows.append((f"fig1/fused_compute_ratio/{name}", 0.0,
                     f"ratio={r:.3f}"))
    rows.append(("fig1/fused_compute_ratio/MEAN", 0.0,
                 f"mean_ratio={np.mean(ratios):.3f}"))
    return rows
