"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On this CPU container interpret-mode timings are NOT TPU performance — the
row exists to exercise the kernels end-to-end and record their block
configurations; TPU perf is the §Roofline analysis.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.sparse.random import banded_spd
from repro.core.tilefusion import api, fused_ref
from repro.kernels import ops, ref

from .util import bench_n, time_fn


def run():
    rows = []
    rng = np.random.default_rng(5)
    # fused FFN (smoke shrinks rows/seq/capacity; block shapes still divide)
    m, d, f = bench_n(512, 256), bench_n(256, 64), bench_n(1024, 512)
    x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((d, f)) * 0.05, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((f, d)) * 0.05, jnp.float32)
    t_k = time_fn(ops.fused_ffn, x, w1, w2, block_m=256, block_f=512)
    t_r = time_fn(ref.ffn, x, w1, w2)
    err = float(jnp.abs(ops.fused_ffn(x, w1, w2) - ref.ffn(x, w1, w2)).max())
    rows.append(("kernels/fused_ffn/pallas_interp", t_k,
                 f"ref_us={t_r:.0f};max_err={err:.2e}"))
    # flash attention
    b, h, s, dh = 1, bench_n(4, 2), bench_n(512, 128), 64
    q = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    t_k = time_fn(ops.flash_attention, q, k, v, block_q=128, block_k=128)
    t_r = time_fn(ref.attention, q, k, v)
    err = float(jnp.abs(ops.flash_attention(q, k, v)
                        - ref.attention(q, k, v)).max())
    rows.append(("kernels/flash_attention/pallas_interp", t_k,
                 f"ref_us={t_r:.0f};max_err={err:.2e}"))
    # tile-fused GeMM-SpMM through the dispatch API: every backend on one
    # real schedule (pallas = wavefront-0 kernel, interpret mode on CPU)
    bcol = 64
    n = bench_n(2048)
    a = banded_spd(n, 8, seed=9)
    spec = api.FusionSpec(p=8, cache_size=300_000.0, ct_size=512)
    bb = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((bcol, bcol)), jnp.float32)
    want = fused_ref.unfused_gemm_spmm(a, np.asarray(bb, np.float64),
                                       np.asarray(cc, np.float64))
    ds = api.get_schedule(a, b_col=bcol, c_col=bcol, spec=spec).dsched
    j0, w = ds.ell_cols0.shape[1], ds.ell_cols0.shape[2]
    for be in ("pallas", "xla", "unfused"):
        t_k = time_fn(api.tile_fused_matmul, a, bb, cc, backend=be,
                      spec=spec)
        err = float(np.abs(np.asarray(
            api.tile_fused_matmul(a, bb, cc, backend=be,
                                  spec=spec)) - want).max())
        rows.append((f"kernels/tile_fused_gemm_spmm/{be}", t_k,
                     f"max_err={err:.2e};"
                     f"vmem_tile_t={ops.choose_kernel_tile(bcol, bcol, j0, w)}"))
    # moe
    e, cap = bench_n(8, 2), bench_n(256, 128)
    xm = jnp.asarray(rng.standard_normal((e, cap, d)), jnp.float32)
    w1m = jnp.asarray(rng.standard_normal((e, d, f)) * 0.05, jnp.float32)
    w2m = jnp.asarray(rng.standard_normal((e, f, d)) * 0.05, jnp.float32)
    t_k = time_fn(ops.fused_moe_ffn, xm, w1m, w2m, block_c=128, block_f=512)
    err = float(jnp.abs(ops.fused_moe_ffn(xm, w1m, w2m)
                        - ref.moe_ffn(xm, w1m, w2m)).max())
    rows.append(("kernels/fused_moe_ffn/pallas_interp", t_k,
                 f"max_err={err:.2e}"))
    return rows
