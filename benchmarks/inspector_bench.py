"""Inspector cost: vectorized O(nnz) pipeline vs the loop-based reference.

The §4.2.3 amortization argument needs a cheap inspector; this driver
measures how cheap.  For each ≥50k-row synthetic pattern it times

  * the retained row-at-a-time reference (``core.tilefusion.reference``) —
    the pre-vectorization Algorithm 1 + nested-loop ELL packing, and
  * the production vectorized inspector (``build_schedule`` +
    ``to_device_schedule``),

and derives the break-even executor step count for both from the Eq-3
traffic model (bytes saved per run at v5e HBM bandwidth, as in fig10).
It also times one full ``autotune=True`` sweep, whose affordability is the
point of the rewrite: sweep cost ≈ grid size × one vectorized inspection.

Target (ISSUE 2 acceptance): ≥ 10× inspector speedup on at least one
≥50k-row pattern.  The power-law graph's historic caveat — a single
max-degree hub row forcing a (tiles, rows, width) padded ELL in the GB
range — is now addressed by the hybrid width cap: each pattern also
reports the capped ``to_device_schedule`` time and the packed-element win
of the hybrid wavefront-1 layout over pad-to-max (the ``powerlaw_hub``
row is the stress case, with one row boosted to degree n/2).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.sparse.formats import hybrid_width_cap
from repro.core.sparse.random import banded_spd, block_diag_noise, \
    hub_powerlaw, powerlaw_graph
from repro.core.tilefusion import api, build_schedule, reference, \
    to_device_schedule

from .util import bench_n

N_FULL = 65_536          # ≥ 50k rows (GNN-scale)
BCOL = 64
KNOBS = dict(p=8, cache_size=300_000.0, ct_size=2048, uniform_split=True)
SPEC = api.FusionSpec(**KNOBS)   # the same knobs, as the api's spec object
HBM_BYTES_PER_S = 819e9  # v5e


def _time_once(fn):
    """(seconds, result) of one call — results are reused, not rebuilt."""
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def _wf1_pack_stats(a, sched, ds_cap):
    """Packed wavefront-1 elements: hybrid (the materialized ``ds_cap``) vs
    pad-to-max (computed *analytically* — at GNN scale with a hub row the
    pad-to-max arrays are the GB-range allocation this format exists to
    avoid)."""
    wf1 = sched.wavefronts[1]
    counts = np.diff(a.indptr).astype(np.int64)
    if wf1:
        j1_max = max(tl.n_j for tl in wf1)
        w_max = max((int(counts[tl.j_rows].max()) for tl in wf1
                     if tl.j_rows.size), default=1)
        pad_elems = len(wf1) * max(j1_max, 1) * max(w_max, 1)
    else:
        pad_elems = 0
    cap_elems = int(ds_cap.ell_cols1.size) + int(ds_cap.spill_rows1.size)
    return pad_elems, cap_elems


def run():
    rows = []
    n = bench_n(N_FULL, smoke_n=2048)
    mats = {
        "banded_spd_b8": banded_spd(n, 8, seed=6),
        "blockdiag_512": block_diag_noise(n, 512, seed=7),
        "powerlaw_d8": powerlaw_graph(n, 8, seed=8),
    }
    for name, a in mats.items():
        def _vec_inspect():
            sched = build_schedule(a, b_col=BCOL, c_col=BCOL, **KNOBS)
            return sched, to_device_schedule(a, sched)
        t_vec, (sched, _) = _time_once(_vec_inspect)
        t_ref, _ = _time_once(lambda: reference.to_device_schedule_ref(
            a, reference.build_schedule_ref(a, b_col=BCOL, c_col=BCOL,
                                            **KNOBS)))
        api.clear_schedule_cache()
        entry = api.get_schedule(a, b_col=BCOL, c_col=BCOL, spec=SPEC)
        tm = entry.traffic_model
        gain_s = (tm["unfused_bytes"] - tm["fused_bytes"]) / HBM_BYTES_PER_S
        breakeven = lambda t: f"{t / gain_s:.0f}" if gain_s > 0 else "inf"
        t0 = time.perf_counter()
        at = api.get_schedule(a, b_col=BCOL, c_col=BCOL,
                              spec=dataclasses.replace(SPEC, autotune=True))
        t_sweep = time.perf_counter() - t0
        # hybrid wavefront-1 packing: capped build time + memory vs pad-to-max
        cap = hybrid_width_cap(np.diff(a.indptr))
        t_cap, ds_cap = _time_once(
            lambda: to_device_schedule(a, sched, width_cap=cap))
        pad_elems, cap_elems = _wf1_pack_stats(a, sched, ds_cap)
        rows.append((
            f"inspector/{name}/n{n}", t_vec * 1e6,
            f"ref_us={t_ref * 1e6:.0f};speedup={t_ref / t_vec:.1f}x;"
            f"breakeven_steps_ref={breakeven(t_ref)};"
            f"breakeven_steps_vec={breakeven(t_vec)};"
            f"autotune_sweep_us={t_sweep * 1e6:.0f};"
            f"autotune_pick={at.autotuned};"
            f"hybrid_cap={cap};hybrid_pack_us={t_cap * 1e6:.0f};"
            f"wf1_elems_padmax={pad_elems};wf1_elems_hybrid={cap_elems};"
            f"wf1_mem_win={pad_elems / max(cap_elems, 1):.1f}x"))

    # hub-row stress case: the capped inspector runs where pad-to-max would
    # allocate n × max_deg — pad-to-max is only ever computed analytically
    a = hub_powerlaw(n, seed=9)
    cap = hybrid_width_cap(np.diff(a.indptr))
    sched = build_schedule(a, b_col=BCOL, c_col=BCOL, **KNOBS)
    t_cap, ds_cap = _time_once(
        lambda: to_device_schedule(a, sched, width_cap=cap))
    pad_elems, cap_elems = _wf1_pack_stats(a, sched, ds_cap)
    rows.append((
        f"inspector/powerlaw_hub/n{n}", t_cap * 1e6,
        f"hybrid_cap={cap};max_deg={int(np.diff(a.indptr).max())};"
        f"wf1_elems_padmax={pad_elems};wf1_elems_hybrid={cap_elems};"
        f"wf1_mem_win={pad_elems / max(cap_elems, 1):.1f}x"))
    return rows
