"""Inspector cost: vectorized O(nnz) pipeline vs the loop-based reference.

The §4.2.3 amortization argument needs a cheap inspector; this driver
measures how cheap.  For each ≥50k-row synthetic pattern it times

  * the retained row-at-a-time reference (``core.tilefusion.reference``) —
    the pre-vectorization Algorithm 1 + nested-loop ELL packing, and
  * the production vectorized inspector (``build_schedule`` +
    ``to_device_schedule``),

and derives the break-even executor step count for both from the Eq-3
traffic model (bytes saved per run at v5e HBM bandwidth, as in fig10).
It also times one full ``autotune=True`` sweep, whose affordability is the
point of the rewrite: sweep cost ≈ grid size × one vectorized inspection.

Target (ISSUE 2 acceptance): ≥ 10× inspector speedup on at least one
≥50k-row pattern.  The power-law graph is reported too but is not the
headline: its single max-degree hub row forces a (tiles, rows, width)
padded ELL in the GB range, and that allocation — a property of the ELL
format, paid identically by both packers — floors the ratio.
"""
from __future__ import annotations

import time

from repro.core.sparse.random import banded_spd, block_diag_noise, \
    powerlaw_graph
from repro.core.tilefusion import api, build_schedule, reference, \
    to_device_schedule

from .util import bench_n

N_FULL = 65_536          # ≥ 50k rows (GNN-scale)
BCOL = 64
KNOBS = dict(p=8, cache_size=300_000.0, ct_size=2048, uniform_split=True)
HBM_BYTES_PER_S = 819e9  # v5e


def _time_once(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run():
    rows = []
    n = bench_n(N_FULL, smoke_n=2048)
    mats = {
        "banded_spd_b8": banded_spd(n, 8, seed=6),
        "blockdiag_512": block_diag_noise(n, 512, seed=7),
        "powerlaw_d8": powerlaw_graph(n, 8, seed=8),
    }
    for name, a in mats.items():
        t_vec = _time_once(lambda: to_device_schedule(
            a, build_schedule(a, b_col=BCOL, c_col=BCOL, **KNOBS)))
        t_ref = _time_once(lambda: reference.to_device_schedule_ref(
            a, reference.build_schedule_ref(a, b_col=BCOL, c_col=BCOL,
                                            **KNOBS)))
        api.clear_schedule_cache()
        entry = api.get_schedule(a, b_col=BCOL, c_col=BCOL, **KNOBS)
        tm = entry.traffic_model
        gain_s = (tm["unfused_bytes"] - tm["fused_bytes"]) / HBM_BYTES_PER_S
        breakeven = lambda t: f"{t / gain_s:.0f}" if gain_s > 0 else "inf"
        t0 = time.perf_counter()
        at = api.get_schedule(a, b_col=BCOL, c_col=BCOL, autotune=True,
                              **KNOBS)
        t_sweep = time.perf_counter() - t0
        rows.append((
            f"inspector/{name}/n{n}", t_vec * 1e6,
            f"ref_us={t_ref * 1e6:.0f};speedup={t_ref / t_vec:.1f}x;"
            f"breakeven_steps_ref={breakeven(t_ref)};"
            f"breakeven_steps_vec={breakeven(t_vec)};"
            f"autotune_sweep_us={t_sweep * 1e6:.0f};"
            f"autotune_pick={at.autotuned}"))
    return rows
