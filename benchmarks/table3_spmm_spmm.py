"""Table 3 analogue: SpMM-SpMM (D = A(AC)) fused vs unfused speedups.

Paper: 1.02-1.22× gmean (memory-bound, smaller win than GeMM-SpMM).
Same container caveat as table2 — traffic_saving is the kernel-path metric.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.tilefusion import api

from .util import bench_n, bench_suite, gmean, sweep, time_fn

N = 2048
P = 8
CACHE = 300_000.0
KNOBS = dict(p=P, cache_size=CACHE, ct_size=512)


def run():
    rows = []
    n = bench_n(N)
    suite = bench_suite(N)
    rng = np.random.default_rng(1)
    for ccol in sweep((32, 64, 128), (32,)):
        speedups, savings = {}, {}
        for name, a in suite.items():
            c = jnp.asarray(rng.standard_normal((n, ccol)), jnp.float32)
            entry = api.get_schedule(a, b_col=ccol, c_col=ccol,
                                     b_is_sparse=True, **KNOBS)
            sched = entry.sched
            t_f = time_fn(api.tile_fused_matmul, a, a, c, backend="xla",
                          **KNOBS)
            t_u = time_fn(api.tile_fused_matmul, a, a, c, backend="unfused",
                          **KNOBS)
            tm = entry.traffic_model
            speedups[name] = t_u / t_f
            savings[name] = tm["traffic_saving"]
            rows.append((
                f"table3/spmm_spmm/{name}/ccol{ccol}/fused", t_f,
                f"speedup={t_u/t_f:.2f};fused_ratio={sched.fused_ratio:.2f};"
                f"traffic_saving={tm['traffic_saving']:.2f}"))
        rows.append((f"table3/spmm_spmm/GMEAN/ccol{ccol}", 0.0,
                     f"gmean_speedup={gmean(speedups.values()):.3f};"
                     f"mean_traffic_saving={np.mean(list(savings.values())):.3f}"))
    return rows
