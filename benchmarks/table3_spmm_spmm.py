"""Table 3 analogue: SpMM-SpMM (D = A(AC)) fused vs unfused speedups.

Paper: 1.02-1.22× gmean (memory-bound, smaller win than GeMM-SpMM).
Same container caveat as table2 — traffic_saving is the kernel-path metric.

Beyond the paper: the fused timing now covers both executors — the XLA
vmapped one and the wavefront-0 Pallas kernel (compiled on TPU, interpret
elsewhere) — and a hub-boosted power-law row reports the hybrid-ELL
width/memory win: packed elements at the auto width cap vs the pad-to-max
packer a single max-degree row used to force.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.sparse.formats import hybrid_width_cap
from repro.core.sparse.random import hub_powerlaw
from repro.core.tilefusion import api
from repro.core.tilefusion.cost_model import hybrid_packed_elements

from .util import bench_n, bench_suite, gmean, sweep, time_fn

N = 2048
P = 8
CACHE = 300_000.0
SPEC = api.FusionSpec(p=P, cache_size=CACHE, ct_size=512)


def run():
    rows = []
    n = bench_n(N)
    suite = dict(bench_suite(N), powerlaw_hub=hub_powerlaw(n, seed=5))
    rng = np.random.default_rng(1)
    for ccol in sweep((32, 64, 128), (32,)):
        speedups, savings = {}, {}
        for name, a in suite.items():
            c = jnp.asarray(rng.standard_normal((n, ccol)), jnp.float32)
            entry = api.get_schedule(a, b_col=ccol, c_col=ccol,
                                     b_is_sparse=True, spec=SPEC)
            sched = entry.sched
            t_f = time_fn(api.tile_fused_matmul, a, a, c, backend="xla",
                          spec=SPEC)
            t_p = time_fn(api.tile_fused_matmul, a, a, c, backend="pallas",
                          spec=SPEC)
            t_u = time_fn(api.tile_fused_matmul, a, a, c, backend="unfused",
                          spec=SPEC)
            tm = entry.traffic_model
            speedups[name] = t_u / t_f
            savings[name] = tm["traffic_saving"]
            rows.append((
                f"table3/spmm_spmm/{name}/ccol{ccol}/fused", t_f,
                f"speedup={t_u/t_f:.2f};fused_ratio={sched.fused_ratio:.2f};"
                f"traffic_saving={tm['traffic_saving']:.2f}"))
            rows.append((
                f"table3/spmm_spmm/{name}/ccol{ccol}/pallas", t_p,
                f"speedup={t_u/t_p:.2f};width_cap={entry.width_cap}"))
        rows.append((f"table3/spmm_spmm/GMEAN/ccol{ccol}", 0.0,
                     f"gmean_speedup={gmean(speedups.values()):.3f};"
                     f"mean_traffic_saving={np.mean(list(savings.values())):.3f}"))

    # hybrid-ELL width/memory win on the hub row (format-level, time-free)
    a = suite["powerlaw_hub"]
    counts = np.diff(a.indptr)
    cap = hybrid_width_cap(counts)
    packed = hybrid_packed_elements(counts, cap)
    pad = int(a.n_rows) * max(int(counts.max()), 1)
    rows.append((
        f"table3/hybrid_ell/powerlaw_hub/n{n}", 0.0,
        f"width_cap={cap};max_deg={int(counts.max())};nnz={a.nnz};"
        f"packed_elems={packed};padmax_elems={pad};"
        f"mem_win={pad / max(packed, 1):.1f}x"))
    return rows
