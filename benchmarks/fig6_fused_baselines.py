"""Figure 6/12 analogue: tile fusion vs prior fusion methods.

Paper: tile fusion beats atomic tiling 13.6×, overlapped tiling 3.5×
(GeMM-SpMM, graph matrices).  Also reports overlapped-tiling redundancy
(replicated iterations), the paper's G2_circuit/inline_1 observation.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.sparse.random import powerlaw_graph, banded_spd
from repro.core.tilefusion import api, fused_ops

from .util import bench_n, gmean, time_fn

N = 2048
P = 8
SPEC = api.FusionSpec(p=P, cache_size=300_000.0, ct_size=512,
                      uniform_split=False)


def run():
    rows = []
    rng = np.random.default_rng(2)
    n = bench_n(N)
    mats = {"powerlaw_d8": powerlaw_graph(n, 8, seed=7),
            "banded_b8": banded_spd(n, 8, seed=8)}
    bcol = 64
    sp_at, sp_ov = [], []
    for name, a in mats.items():
        b = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((bcol, bcol)), jnp.float32)
        t_f = time_fn(api.tile_fused_matmul, a, b, c, backend="xla",
                      spec=SPEC)

        parts = fused_ops.overlapped_tiles(a, P)
        t_ov = time_fn(fused_ops.overlapped_gemm_spmm, a, parts, b, c)
        waves = fused_ops.atomic_tiles(a, P)
        t_at = time_fn(fused_ops.atomic_gemm_spmm, a, waves, b, c)
        red = fused_ops.overlapped_redundancy(a, P)
        sp_at.append(t_at / t_f)
        sp_ov.append(t_ov / t_f)
        rows.append((f"fig6/{name}/tile_fusion", t_f,
                     f"vs_atomic={t_at/t_f:.2f};vs_overlapped={t_ov/t_f:.2f};"
                     f"overlap_redundancy={red:.2f}"))
    rows.append(("fig6/GMEAN", 0.0,
                 f"vs_atomic={gmean(sp_at):.2f};vs_overlapped={gmean(sp_ov):.2f}"))
    return rows
