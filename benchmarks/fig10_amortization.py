"""Figure 10 analogue: runs needed to amortize the scheduler.

runs = scheduler_time / (unfused_time - fused_time).  Paper: < 100 runs for
most matrices (GNN training runs the pair thousands of times).

With the unified API the amortization is *mechanized*: the first
``tile_fused_matmul`` call on a pattern pays the inspector, every later call
hits the content-keyed schedule cache — the second inspection on the same
pattern reports ≈ 0 time.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.tilefusion import api

from .util import bench_n, bench_suite, time_fn

N = 2048
SPEC = api.FusionSpec(p=8, cache_size=300_000.0, ct_size=512,
                      uniform_split=False)


def run():
    rows = []
    rng = np.random.default_rng(4)
    bcol = 64
    n = bench_n(N)
    for name, a in bench_suite(N).items():
        api.clear_schedule_cache()
        b = jnp.asarray(rng.standard_normal((n, bcol)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((bcol, bcol)), jnp.float32)
        # first inspection pays the scheduler; the repeat is a cache hit
        t0 = time.perf_counter()
        entry = api.get_schedule(a, b_col=bcol, c_col=bcol, spec=SPEC)
        t_sched = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        api.get_schedule(a, b_col=bcol, c_col=bcol, spec=SPEC)
        t_cached = (time.perf_counter() - t0) * 1e6
        assert api.schedule_cache_stats()["hits"] >= 1
        t_f = time_fn(api.tile_fused_matmul, a, b, c, backend="xla",
                      spec=SPEC)
        t_u = time_fn(api.tile_fused_matmul, a, b, c, backend="unfused",
                      spec=SPEC)
        gain = t_u - t_f
        runs = t_sched / gain if gain > 0 else float("inf")
        # kernel-path (TPU) amortization: scheduler cost vs the HBM traffic
        # the fused kernel saves per run (819 GB/s v5e).  Numpy scheduler is
        # ~10-100x a production C++ one; both numbers reported.
        tm = entry.traffic_model
        gain_tpu_us = (tm["unfused_bytes"] - tm["fused_bytes"]) / 819e9 * 1e6
        runs_tpu = t_sched / gain_tpu_us if gain_tpu_us > 0 else float("inf")
        rows.append((f"fig10/{name}", t_sched,
                     f"inspector_cached_us={t_cached:.1f};"
                     f"amortize_runs_cpu={runs:.0f};gain_us={gain:.0f};"
                     f"tpu_traffic_gain_us={gain_tpu_us:.1f};"
                     f"amortize_runs_tpu_model={runs_tpu:.0f}"))
    return rows
