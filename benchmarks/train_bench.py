"""Training-path benchmark: fused vs unfused GCN train steps, the
transpose-schedule cache, and a backward-parity gate.

Rows:

* ``train/gcn/{fused,unfused}`` — median wall time of one jitted
  train step (fwd + custom_vjp bwd + SGD update); derived
  ``train_step_ms`` is the headline column, plus the post-run loss.
* ``train/transpose_cache`` — an *eager* training loop so every layer's
  backward actually performs its transpose-schedule lookup (a jitted loop
  looks it up once at trace time); derived ``hit_rate`` is the fraction of
  those lookups served from cache and ``entries`` the live transpose
  entries (one per layer shape when amortization holds).
* ``train/grad_parity`` — max abs error of ``jax.grad`` through
  ``tile_fused_matmul`` vs the dense-reference gradient; threshold-gated
  in benchmarks/thresholds.json (smoke: the backward must stay correct,
  not just fast).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import util
from repro.configs.gcn import GCNConfig
from repro.core.sparse.random import powerlaw_graph
from repro.core.tilefusion import api
from repro.launch.steps import make_gcn_train_step
from repro.models.gcn import GCN


def _setup(n: int):
    cfg = GCNConfig(n_nodes=n, in_dim=64, hidden_dim=64, out_dim=16,
                    n_layers=2)
    adj = powerlaw_graph(cfg.n_nodes, cfg.avg_degree, seed=0)
    model = GCN(cfg, adj, cache_size=300_000.0, ct_size=256)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((cfg.n_nodes, cfg.in_dim)),
                    jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.out_dim, cfg.n_nodes))
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, x, y, params


def run():
    n = util.bench_n(2048, smoke_n=256)
    cfg, model, x, y, params = _setup(n)

    # -- fused vs unfused step time --------------------------------------
    for fused in (True, False):
        step = make_gcn_train_step(model, lr=0.1, fused=fused)
        p, loss = step(params, x, y)            # compile + warm caches
        us = util.time_fn(lambda: step(p, x, y)[1])
        name = f"train/gcn/{'fused' if fused else 'unfused'}"
        yield (name, us,
               f"train_step_ms={us / 1e3:.3f};nodes={n};"
               f"loss={float(loss):.4f}")

    # -- transpose-cache hit rate (eager: each step really looks up) -----
    api.clear_schedule_cache()
    model = GCN(cfg, model.adj, cache_size=300_000.0, ct_size=256)
    step = make_gcn_train_step(model, lr=0.1, jit=False)
    steps = 2 if util.smoke() else 10
    p, _ = step(params, x, y)       # warming step mints the entries once
    tr0 = api.schedule_cache_stats()["transpose_entries"]
    t0 = time.perf_counter()
    for _ in range(steps):
        p, _ = step(p, x, y)
    us = (time.perf_counter() - t0) / steps * 1e6
    st = api.schedule_cache_stats()
    lookups = steps * cfg.n_layers
    misses = st["transpose_entries"] - tr0
    yield ("train/transpose_cache", us,
           f"hit_rate={1.0 - misses / lookups:.3f};"
           f"entries={st['transpose_entries']};lookups={lookups}")

    # -- backward parity gate --------------------------------------------
    a = model.adj
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal((a.n_cols, 32)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((a.n_rows, 16)), jnp.float32)
    ad = jnp.asarray(a.to_dense(), jnp.float32)
    t0 = time.perf_counter()
    gb, gc = jax.grad(lambda b_, c_: jnp.sum(
        w * api.tile_fused_matmul(
            a, b_, c_, backend="xla",
            spec=api.FusionSpec(cache_size=300_000.0, ct_size=256))),
        argnums=(0, 1))(b, c)
    us = (time.perf_counter() - t0) * 1e6
    rb, rc = jax.grad(lambda b_, c_: jnp.sum(w * (ad @ (b_ @ c_))),
                      argnums=(0, 1))(b, c)
    err = max(float(jnp.abs(gb - rb).max()), float(jnp.abs(gc - rc).max()))
    yield ("train/grad_parity", us, f"max_err={err:.2e};nodes={n}")
