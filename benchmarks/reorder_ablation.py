"""Beyond-paper: RCM reordering ablation on the fused ratio.

The paper's fused ratio is bandwidth-limited; RCM reordering (one-off,
amortized like the scheduler) should lift it on graph matrices — the
paper's weakest case (graph ratios ~2x below SPD, §4.2.1).

Two gated additions (ISSUE 10):

* ``reorder/auto_never_worse/*`` prices the ``spec.reorder="auto"``
  schedule transform against ``reorder=None`` on every matrix —
  ``traffic_ratio`` (auto fused bytes / identity fused bytes) must never
  exceed 1.0, the by-construction guarantee of the Eq-3 floor.
* ``reorder/rcm_time/large_component`` times ``rcm_order`` on one large
  near-single-component banded matrix.  Regression note: the BFS queue
  must stay a ``collections.deque`` — the old ``list.pop(0)`` is linear
  per pop, which turned this exact case O(n²) (tens of seconds at the
  full 65k-row size vs milliseconds with ``popleft``).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.sparse.random import banded_spd, powerlaw_graph, \
    block_diag_noise
from repro.core.tilefusion import api
from repro.core.tilefusion.reorder import bandwidth, permute_csr, rcm_order

from .util import bench_n


def run():
    rows = []
    n = bench_n(4096)
    mats = {
        "powerlaw_d4": powerlaw_graph(n, 4, seed=11),
        "powerlaw_d8": powerlaw_graph(n, 8, seed=12),
        "blockdiag_shuffled": permute_csr(
            block_diag_noise(n, min(512, n // 2), seed=13),
            np.random.default_rng(0).permutation(n)),
    }
    spec = api.FusionSpec(p=8, cache_size=1e12, ct_size=512,
                          uniform_split=False)
    for name, a in mats.items():
        r0 = api.get_schedule(a, b_col=64, c_col=64,
                              spec=spec).sched.fused_ratio
        perm = rcm_order(a)
        a2 = permute_csr(a, perm)
        r1 = api.get_schedule(a2, b_col=64, c_col=64,
                              spec=spec).sched.fused_ratio
        rows.append((f"reorder/{name}", 0.0,
                     f"ratio_before={r0:.3f};ratio_after={r1:.3f};"
                     f"bw_before={bandwidth(a)};bw_after={bandwidth(a2)}"))
        # the reorder="auto" schedule transform must never raise modeled
        # Eq-3 traffic over the identity ordering (gated, smoke-safe)
        base = api.get_schedule(a, b_col=64, c_col=64, spec=spec)
        auto = api.get_schedule(
            a, b_col=64, c_col=64,
            spec=dataclasses.replace(spec, reorder="auto"))
        ratio = (auto.traffic_model["fused_bytes"]
                 / max(base.traffic_model["fused_bytes"], 1.0))
        rows.append((f"reorder/auto_never_worse/{name}", 0.0,
                     f"traffic_ratio={ratio:.4f};"
                     f"applied={auto.reorder or 'none'}"))
    # deque-BFS timing regression canary: one big single-component matrix
    big = banded_spd(bench_n(65_536, smoke_n=1024), bandwidth=4, seed=5)
    t0 = time.perf_counter()
    rcm_order(big)
    rows.append(("reorder/rcm_time/large_component",
                 (time.perf_counter() - t0) * 1e6,
                 f"n={big.n_rows};nnz={big.nnz}"))
    return rows
