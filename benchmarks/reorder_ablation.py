"""Beyond-paper: RCM reordering ablation on the fused ratio.

The paper's fused ratio is bandwidth-limited; RCM reordering (one-off,
amortized like the scheduler) should lift it on graph matrices — the
paper's weakest case (graph ratios ~2x below SPD, §4.2.1).
"""
from __future__ import annotations

import numpy as np

from repro.core.sparse.random import powerlaw_graph, block_diag_noise
from repro.core.tilefusion import api
from repro.core.tilefusion.reorder import bandwidth, permute_csr, rcm_order

from .util import bench_n


def run():
    rows = []
    n = bench_n(4096)
    mats = {
        "powerlaw_d4": powerlaw_graph(n, 4, seed=11),
        "powerlaw_d8": powerlaw_graph(n, 8, seed=12),
        "blockdiag_shuffled": permute_csr(
            block_diag_noise(n, min(512, n // 2), seed=13),
            np.random.default_rng(0).permutation(n)),
    }
    spec = api.FusionSpec(p=8, cache_size=1e12, ct_size=512,
                          uniform_split=False)
    for name, a in mats.items():
        r0 = api.get_schedule(a, b_col=64, c_col=64,
                              spec=spec).sched.fused_ratio
        perm = rcm_order(a)
        a2 = permute_csr(a, perm)
        r1 = api.get_schedule(a2, b_col=64, c_col=64,
                              spec=spec).sched.fused_ratio
        rows.append((f"reorder/{name}", 0.0,
                     f"ratio_before={r0:.3f};ratio_after={r1:.3f};"
                     f"bw_before={bandwidth(a)};bw_after={bandwidth(a2)}"))
    return rows
