from .analysis import Roofline, collective_bytes, model_flops, roofline

__all__ = ["Roofline", "collective_bytes", "model_flops", "roofline"]
