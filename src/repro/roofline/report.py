"""Aggregate dry-run JSONs into the §Roofline / §Dry-run markdown tables.

  PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def load(dirpath: str):
    out = []
    for f in sorted(os.listdir(dirpath)):
        if f.endswith(".json"):
            with open(os.path.join(dirpath, f)) as fh:
                out.append(json.load(fh))
    return out


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_table(results):
    rows = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
            "| bottleneck | MODEL/HLO flops | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda x: (x["arch"], x["shape"])):
        if "roofline" not in r:
            continue
        rl = r["roofline"]
        dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        # fraction of roofline: ideal time (compute term with 100% useful
        # flops) over the dominant achievable term
        ideal = rl["model_flops_per_device"] / 197e12
        frac = ideal / dom if dom > 0 else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['compute_s']:.2e} | {rl['memory_s']:.2e} "
            f"| {rl['collective_s']:.2e} | **{rl['bottleneck']}** "
            f"| {rl['useful_ratio']:.2f} | {frac:.3f} |")
    return "\n".join(rows)


def dryrun_table(results):
    rows = ["| arch | shape | mesh | compile (s) | peak mem/device "
            "| args/device | collectives (AG/AR/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(results, key=lambda x: (x["arch"], x["shape"],
                                            x["mesh"])):
        m = r["memory_analysis"]
        c = r.get("collectives", {}).get("bytes", {})
        cstr = "/".join(fmt_bytes(c.get(k)) if c else "-" for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute")) if c else "n/a"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compile_s']} | {fmt_bytes(m['peak_bytes'])} "
            f"| {fmt_bytes(m['argument_bytes'])} | {cstr} |")
    return "\n".join(rows)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    results = load(d)
    single = [r for r in results if not r["multi_pod"]]
    multi = [r for r in results if r["multi_pod"]]
    print("## Roofline (single-pod 16x16)\n")
    print(roofline_table(single))
    print(f"\n## Dry-run: single-pod ({len(single)} cells)\n")
    print(dryrun_table(single))
    print(f"\n## Dry-run: multi-pod 2x16x16 ({len(multi)} cells)\n")
    print(dryrun_table(multi))


if __name__ == "__main__":
    main()
