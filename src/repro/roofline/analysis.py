"""Roofline terms from a compiled dry-run artifact.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

HLO FLOPs/bytes come from ``compiled.cost_analysis()`` (post-SPMD,
per-device module).  Collective bytes are NOT in cost_analysis — we parse
the optimized HLO text and sum result sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re

from ..launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = bf16[16,512,128]{2,1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s+(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" +
    "|".join(_COLLECTIVES) + r")[\.\(]")
# tuple-result collectives:  = (bf16[...], bf16[...]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")[\.\(]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _size_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes in the (per-device) module."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _size_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dt, dims in _SHAPE_RE.findall(shapes):
                out[kind] += _size_bytes(dt, dims)
            counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_per_device: float
    useful_ratio: float

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline(cost: dict, coll: dict, *, model_flops_global: float,
             n_devices: int, peak=PEAK_FLOPS_BF16, hbm=HBM_BW,
             ici=ICI_BW_PER_LINK) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    by = float(cost.get("bytes accessed", 0.0))
    cb = float(coll["total_bytes"])
    terms = {
        "compute": flops / peak,
        "memory": by / hbm,
        "collective": cb / ici,
    }
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_global / n_devices
    return Roofline(
        flops=flops, bytes_accessed=by, coll_bytes=cb,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], bottleneck=bottleneck,
        model_flops_per_device=mf,
        useful_ratio=(mf / flops) if flops else 0.0,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N_active·D for inference."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
