"""Model/config schema shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | enc-dec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention flavour
    attn_bias: bool = False        # qwen2.5 QKV bias
    mla: bool = False              # minicpm3 multi-head latent attention
    mla_kv_rank: int = 256
    rope: str = "rope"             # rope | mrope(→rope for stub) | none
    window: int = 0                # sliding-window size (0 = full attention)
    is_encoder: bool = False
    # moe
    n_experts: int = 0
    moe_top_k: int = 0
    moe_shared_expert: bool = False
    # ssm / hybrid / sparse
    # attn | mlstm | mlstm7+slstm | attn+mamba | sparse-band
    block_pattern: str = "attn"
    ssm_state: int = 16
    ssm_head_dim: Optional[int] = None
    band_window: int = 32          # sparse-band mixer: band width ...
    band_decay: float = 0.9        # ... and per-step decay
    # enc-dec / frontends
    encoder_layers: int = 0
    encoder_seq: int = 1500        # whisper audio frames after conv stub
    frontend: str = "none"         # none | audio | vision
    # numerics
    act: str = "silu"
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # §Perf iteration 4: "dots" (save matmul outputs) beat "full" remat on
    # every roofline term at equal peak memory — framework default.
    remat: str = "dots"            # none | full | dots
    # Fully unroll layer scans.  Compile-time O(L) instead of O(1); used by
    # the dry-run because XLA cost_analysis counts a while body ONCE — the
    # roofline needs the true per-step FLOPs/bytes/collectives.
    scan_unroll: bool = False
    # which input shapes apply (dry-run applicability, DESIGN.md §4)
    skip_shapes: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_head_dim is None:
            object.__setattr__(self, "ssm_head_dim", self.head_dim)

    # ---- parameter counts (roofline MODEL_FLOPS = 6·N·D) ----
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * h * dh + 2 * d * hkv * dh + h * dh * d
        if self.mla:
            r = self.mla_kv_rank
            attn = d * h * dh + d * r + 2 * r * h * dh + h * dh * d
        if self.n_experts:
            e_used = self.moe_top_k if active_only else self.n_experts
            ffn = e_used * 3 * d * f + d * self.n_experts  # router
            if self.moe_shared_expert:
                ffn += 3 * d * f
        else:
            ffn = 3 * d * f
        inner = h * (self.ssm_head_dim or dh)
        mlstm = 2 * d * inner + 3 * inner * inner + inner * d
        mamba = 2 * d * inner + 2 * inner * h * self.ssm_state + inner * d
        if self.block_pattern == "attn":
            per_layer = attn + ffn
        elif self.block_pattern == "mlstm7+slstm":
            per_layer = mlstm  # sLSTM blocks are similar order; counted same
        elif self.block_pattern == "attn+mamba":
            per_layer = attn + mamba + ffn
        elif self.block_pattern == "sparse-band":
            per_layer = 3 * d * inner + ffn   # wv, wz, w_down
        else:
            per_layer = attn + ffn
        total = self.n_layers * per_layer + 2 * v * d
        if self.encoder_layers:
            total += self.encoder_layers * (attn + 3 * d * f)
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
