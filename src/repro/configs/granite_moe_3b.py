"""granite-moe-3b-a800m — MoE decoder [hf:ibm-granite; hf].

32L, d_model=1536, 24H (GQA kv=8), expert d_ff=512, vocab=49155,
MoE 40 experts top-8 (the assignment header also says "32 experts top-8";
we follow the explicit shape spec: 40e top-8).

Tile-fusion flagship arch: expert dispatch is the sparse A (DESIGN.md §4).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    n_experts=40, moe_top_k=8,
    act="silu", skip_shapes=("long_500k",),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
    vocab_size=256, n_experts=4, moe_top_k=2, remat="none")
