"""qwen2-vl-72b — VLM text backbone [arXiv:2409.12191; hf].

80L, d_model=8192, 64H (GQA kv=8), d_ff=29568, vocab=152064.
The vision frontend (dynamic resolution, patch merger) is a STUB:
input_specs provides precomputed patch embeddings.  M-RoPE degenerates to
1-D RoPE for the text-only backbone (DESIGN.md §2).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    rope="mrope", frontend="vision",
    act="silu", skip_shapes=("long_500k",),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, remat="none")
