"""qwen2.5-3b — dense decoder, GQA + QKV bias [hf:Qwen/Qwen2.5; hf].

36L, d_model=2048, 16H (GQA kv=2), d_ff=11008, vocab=151936.
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab_size=151936, head_dim=128,
    attn_bias=True, act="silu", skip_shapes=("long_500k",),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, remat="none")
