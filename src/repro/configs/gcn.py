"""GCN — the paper's native application (Kipf & Welling GCN layer is exactly
``D = A(XW)`` = GeMM-SpMM with A the normalized adjacency)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn"
    n_nodes: int = 4096
    in_dim: int = 128
    hidden_dim: int = 128
    out_dim: int = 32
    n_layers: int = 2
    avg_degree: int = 8


CONFIG = GCNConfig()
REDUCED = GCNConfig(n_nodes=256, in_dim=16, hidden_dim=16, out_dim=8)
