"""xlstm-1.3b — recurrent xLSTM stack [arXiv:2405.04517; unverified].

48L, d_model=2048, 4 heads, vocab=50304, d_ff=0 (blocks carry their own 2×
up-projection).  Pattern: groups of 7 mLSTM + 1 sLSTM.  Attention-free ⇒
sub-quadratic; long_500k runs natively (matrix-memory state, no KV cache).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern="mlstm7+slstm",
    act="gelu",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=2, n_kv_heads=2,
    vocab_size=256, remat="none")
