"""minicpm3-4b — dense decoder with MLA [hf:openbmb/MiniCPM3-4B; hf].

62L, d_model=2560, 40H, d_ff=6400, vocab=73448.  Multi-head latent attention:
the KV cache stores a rank-256 latent; K/V are re-expanded per use (the
paper-style fused two-matmul chain — DESIGN.md §4).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    mla=True, mla_kv_rank=256,
    act="silu", skip_shapes=("long_500k",),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, mla_kv_rank=32, remat="none")
