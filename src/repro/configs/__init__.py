"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig
from . import (gcn, granite_moe_3b, hymba_1_5b, llama4_scout, minicpm3_4b,
               minitron_8b, qwen2_5_3b, qwen2_vl_72b, stablelm_1_6b,
               whisper_medium, xlstm_1_3b)

_MODULES = {
    "whisper-medium": whisper_medium,
    "stablelm-1.6b": stablelm_1_6b,
    "minicpm3-4b": minicpm3_4b,
    "minitron-8b": minitron_8b,
    "qwen2.5-3b": qwen2_5_3b,
    "granite-moe-3b-a800m": granite_moe_3b,
    "llama4-scout-17b-a16e": llama4_scout,
    "xlstm-1.3b": xlstm_1_3b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "hymba-1.5b": hymba_1_5b,
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod = _MODULES[name]
    return mod.REDUCED if reduced else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells():
    """All (arch, shape) dry-run cells, with applicability filtering."""
    out = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if shape_name in cfg.skip_shapes:
                continue
            out.append((arch, shape_name))
    return out


__all__ = ["ARCH_NAMES", "SHAPES", "get_config", "get_shape", "cells",
           "ModelConfig", "ShapeConfig", "gcn"]
