"""whisper-medium — enc-dec audio LM backbone [arXiv:2212.04356; unverified].

24L encoder + 24L decoder, d_model=1024, 16H (kv=16), d_ff=4096, vocab=51865.
Conv audio frontend is a STUB: input_specs provides precomputed frame
embeddings (B, 1500, d_model).  Adaptation: sinusoidal/learned positions are
replaced with RoPE (DESIGN.md §2 hardware-adaptation notes); full attention ⇒
long_500k skipped.
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="enc-dec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    encoder_layers=24, encoder_seq=1500, frontend="audio",
    act="gelu", skip_shapes=("long_500k",),
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, encoder_seq=16, remat="none")
