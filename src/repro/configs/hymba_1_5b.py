"""hymba-1.5b — hybrid parallel attention+mamba heads [arXiv:2411.13676; hf].

32L, d_model=1600, 25H (GQA kv=5), d_ff=5504, ssm_state=16.  Each block runs
sliding-window attention (window=1024) and mamba heads in parallel on the
same input, averaged — the sliding window makes the score matrix
block-sparse (tile-fusion applicability, DESIGN.md §4) and long_500k
runnable with a ring-buffer KV cache.
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    block_pattern="attn+mamba", ssm_state=16, window=1024,
    act="silu",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, window=32, remat="none")
