"""llama4-scout-17b-a16e — MoE decoder [hf:meta-llama/Llama-4-Scout; unverified].

48L, d_model=5120, 40H (GQA kv=8), expert d_ff=8192, vocab=202048,
MoE 16 experts top-1 + shared expert; chunked-local attention (window=8192)
following Llama-4's iRoPE local layers — which also makes long_500k runnable
(ring-buffer KV of 8192 slots).  Early fusion frontend is out of scope for
the text backbone (DESIGN.md).
"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    n_experts=16, moe_top_k=1, moe_shared_expert=True,
    window=8192,
    act="silu",
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab_size=256, head_dim=16, n_experts=4, moe_top_k=1, window=32,
    remat="none")
