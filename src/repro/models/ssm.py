"""Sub-quadratic sequence mixers: chunked gated linear recurrence.

One engine serves both assigned recurrent families (DESIGN.md §4):
  * xLSTM mLSTM blocks (matrix memory + exponential gating) — xlstm-1.3b
  * Mamba/SSD-style selective SSM heads — hymba-1.5b

State per head: H ∈ R^{dk × (dv+1)} — the extra column accumulates the
normalizer (the "ones trick": v is augmented with a ones column, so
H[:, -1] = n_t and o = qH[:,:dv] / max(|qH[:,-1]|, 1)).

The chunked form is the tile-fusion structure on the time axis: a chunk is a
fused tile (intra-chunk work is a pair of matmuls whose intermediate never
leaves VMEM), the carried state is the single wavefront-1-style dependency.

  H_t = a_t·H_{t-1} + k_tᵀ v_t,   o_t = q_t·H_t,   a_t ∈ (0,1) per head
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sparse.formats import CSR
from ..core.tilefusion import api as tf_api


def chunked_linear_recurrence(q, k, v, log_a, *, chunk: int = 128,
                              h0=None, normalize: bool = True):
    """q,k: (B,S,H,dk); v: (B,S,H,dv); log_a: (B,S,H) log-decay (<= 0).

    Returns (o: (B,S,H,dv), h_final: (B,H,dk,dv[+1])).
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
        dv_aug = dv + 1
    else:
        dv_aug = dv
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q).astype(f32), to_chunks(k).astype(f32), \
        to_chunks(v).astype(f32)
    lac = to_chunks(log_a).astype(f32)                     # (nc, b, L, h)

    if h0 is None:
        h0 = jnp.zeros((b, h, dk, dv_aug), f32)

    def step(hstate, xs):
        qb, kb, vb, la = xs                                # (b,L,h,*)
        cum = jnp.cumsum(la, axis=1)                       # inclusive ∑ log a
        total = cum[:, -1]                                 # (b,h)
        # inter-chunk: o_i += (A_i) q_i · H0
        qdec = qb * jnp.exp(cum)[..., None]
        o_inter = jnp.einsum("blhk,bhkv->blhv", qdec, hstate)
        # intra-chunk: S_ij = (q_i·k_j) exp(cum_i - cum_j), j <= i.
        # Mask in LOG space: for j > i the exponent is positive and exp()
        # overflows — inf·0 in the masked branch would poison gradients.
        scores = jnp.einsum("blhk,bmhk->bhlm", qb, kb)
        decay = cum[..., None].swapaxes(1, 2) - cum.transpose(0, 2, 1)[:, :, None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(mask[None, None], decay, -jnp.inf)
        scores = scores * jnp.exp(decay)
        o_intra = jnp.einsum("bhlm,bmhv->blhv", scores, vb)
        # state update: H' = A_L H0 + Σ_j (A_L/A_j) k_jᵀ v_j
        kdec = kb * jnp.exp(total[:, None] - cum)[..., None]
        h_new = hstate * jnp.exp(total)[..., None, None] + \
            jnp.einsum("blhk,blhv->bhkv", kdec, vb)
        return h_new, o_inter + o_intra

    h_final, oc = jax.lax.scan(step, h0, (qc, kc, vc, lac))
    o = oc.swapaxes(0, 1).reshape(b, nc * chunk, h, dv_aug)[:, :s]
    if normalize:
        num, den = o[..., :dv], o[..., dv]
        o = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return o.astype(q.dtype), h_final


def linear_recurrence_step(q, k, v, log_a, hstate, *, normalize: bool = True):
    """Single decode step.  q,k: (B,H,dk); v: (B,H,dv); log_a: (B,H);
    hstate: (B,H,dk,dv[+1]) carried f32 state."""
    f32 = jnp.float32
    dv = v.shape[-1]
    if normalize:
        v = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], -1)
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    h_new = hstate * a + jnp.einsum(
        "bhk,bhv->bhkv", k.astype(f32), v.astype(f32))
    o = jnp.einsum("bhk,bhkv->bhv", q.astype(f32), h_new)
    if normalize:
        o = o[..., :dv] / jnp.maximum(jnp.abs(o[..., dv]), 1.0)[..., None]
    return o.astype(q.dtype), h_new


# ----------------------------------------------------- banded-decay mixer --
@functools.lru_cache(maxsize=8)
def decay_band_csr(seq: int, window: int, decay: float = 0.9) -> CSR:
    """The fixed-decay linear recurrence unrolled on the time axis:
    ``A[i, j] = (1 - decay) * decay**(i - j)`` for
    ``max(0, i - window + 1) <= j <= i`` — a lower-triangular banded
    operator whose SpMM against values IS the windowed recurrence
    ``o_i = (1-a) Σ_j a^{i-j} v_j``.  The ``(1 - decay)`` scale bounds every
    row sum below 1, so the mixer needs no separate normalizer column.

    Returned as host-side CSR so it routes through the tile-fusion
    inspector like any other sparse operand (memoized: the content-keyed
    schedule cache then hits on every layer and step)."""
    if not (0.0 < decay < 1.0):
        raise ValueError(f"decay must be in (0, 1), got {decay}")
    w = max(1, min(int(window), seq))
    counts = np.minimum(np.arange(seq) + 1, w)
    indptr = np.zeros(seq + 1, np.int32)
    np.cumsum(counts, out=indptr[1:])
    indices = np.concatenate(
        [np.arange(i - c + 1, i + 1) for i, c in enumerate(counts)]
    ).astype(np.int32)
    rows = np.repeat(np.arange(seq), counts)
    data = ((1.0 - decay) * decay ** (rows - indices)).astype(np.float32)
    return CSR(seq, seq, indptr, indices, data)


# one spec drives every band-mixer dispatch; small ``p`` because the band
# is narrow and perfectly local (wavefront 0 swallows almost every tile)
_BAND_SPEC = tf_api.FusionSpec(p=4, cache_size=600_000.0, ct_size=256)


def band_mix_init(key, cfg, dtype):
    """Banded-decay token mixer (``sparse-band`` block pattern): value and
    gate projections plus the down projection."""
    d = cfg.d_model
    inner = cfg.n_heads * cfg.ssm_head_dim
    ks = jax.random.split(key, 3)
    return {
        "wv": _init(ks[0], (d, inner), dtype=dtype),
        "wz": _init(ks[1], (d, inner), dtype=dtype),
        "w_down": _init(ks[2], (inner, d), dtype=dtype),
    }


def band_mix_apply(p, cfg, x, a, *, backend: str = "xla", spec=None):
    """x: (B,S,d) -> (B,S,d); ``a = decay_band_csr(S, ...)``.

    The mix is ``A @ (X Wv)`` — the paper's GeMM-SpMM with the band as the
    sparse operand — routed through ``tile_fused_matmul`` per batch
    element, so the schedule comes from the content-keyed cache and the
    backward runs the fused transposed products (custom_vjp), the same
    differentiable seam the GCN trains through."""
    spec = _BAND_SPEC if spec is None else spec
    wv = p["wv"].astype(jnp.float32)
    mixed = jnp.stack([
        tf_api.tile_fused_matmul(a, x[i].astype(jnp.float32), wv,
                                 backend=backend, spec=spec)
        for i in range(x.shape[0])])
    z = x @ p["wz"]
    return (mixed.astype(x.dtype) * jax.nn.silu(z)) @ p["w_down"]


# ------------------------------------------------------------------ blocks --
def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / (shape[0] ** 0.5)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


def mlstm_init(key, cfg, dtype):
    """xLSTM mLSTM block params: 2x up-proj, per-head q/k/v + f/i gates."""
    d = cfg.d_model
    h = cfg.n_heads
    dh = cfg.ssm_head_dim
    inner = h * dh
    ks = jax.random.split(key, 7)
    return {
        "w_up": _init(ks[0], (d, 2 * inner), dtype=dtype),
        "wq": _init(ks[1], (inner, inner), dtype=dtype),
        "wk": _init(ks[2], (inner, inner), dtype=dtype),
        "wv": _init(ks[3], (inner, inner), dtype=dtype),
        "w_f": _init(ks[4], (inner, h), scale=0.02, dtype=jnp.float32),
        "w_i": _init(ks[5], (inner, h), scale=0.02, dtype=jnp.float32),
        "w_down": _init(ks[6], (inner, d), dtype=dtype),
    }


def mlstm_apply(p, cfg, x, *, cache=None):
    """x: (B,S,d) -> (B,S,d).  cache: carried state for decode (S==1)."""
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.ssm_head_dim
    up = x @ p["w_up"]
    main, gate = jnp.split(up, 2, axis=-1)                 # (b,s,inner)
    q = (main @ p["wq"]).reshape(b, s, h, dh)
    k = (main @ p["wk"]).reshape(b, s, h, dh) / (dh ** 0.5)
    v = (main @ p["wv"]).reshape(b, s, h, dh)
    log_f = jax.nn.log_sigmoid(main.astype(jnp.float32) @ p["w_f"])  # (b,s,h)
    i_gate = jnp.exp(jax.nn.log_sigmoid(main.astype(jnp.float32) @ p["w_i"]))
    k = k * i_gate[..., None].astype(k.dtype)
    if s > 1:   # training or batched prefill (cache = carried-in state)
        o, h_final = chunked_linear_recurrence(
            q, k, v, log_f, chunk=min(128, s), h0=cache)
    else:
        h0 = cache if cache is not None else \
            jnp.zeros((b, h, dh, dh + 1), jnp.float32)
        o, h_final = linear_recurrence_step(
            q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], h0)
        o = o[:, None]
    o = o.reshape(b, s, -1) * jax.nn.silu(gate)
    return o @ p["w_down"], h_final


def slstm_init(key, cfg, dtype):
    """sLSTM block: scalar-memory LSTM with exponential gating (elementwise)."""
    d = cfg.d_model
    inner = cfg.n_heads * cfg.ssm_head_dim
    ks = jax.random.split(key, 3)
    return {
        "w_up": _init(ks[0], (d, 4 * inner), dtype=dtype),   # z, i, f, o gates
        "w_rec": _init(ks[1], (inner, 4 * inner), scale=0.02, dtype=dtype),
        "w_down": _init(ks[2], (inner, d), dtype=dtype),
    }


def slstm_apply(p, cfg, x, *, cache=None):
    b, s, _ = x.shape
    inner = cfg.n_heads * cfg.ssm_head_dim
    pre = (x @ p["w_up"]).astype(jnp.float32)              # (b,s,4*inner)

    def step(carry, u):
        c, hid = carry
        u = u + hid @ p["w_rec"].astype(jnp.float32)
        z, i, f, o = jnp.split(u, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
        hid = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (c, hid), hid

    init = cache if cache is not None else (
        jnp.zeros((b, inner), jnp.float32), jnp.zeros((b, inner), jnp.float32))
    (c, hid), hs = jax.lax.scan(step, init, pre.swapaxes(0, 1))
    out = hs.swapaxes(0, 1).astype(x.dtype) @ p["w_down"]
    return out, (c, hid)


def mamba_init(key, cfg, dtype):
    """Selective-SSM heads (hymba's mamba half), SSD/linear-attention form."""
    d = cfg.d_model
    h, dh, n = cfg.n_heads, cfg.ssm_head_dim, cfg.ssm_state
    inner = h * dh
    ks = jax.random.split(key, 5)
    return {
        "w_in": _init(ks[0], (d, 2 * inner), dtype=dtype),   # x and z branch
        "w_bc": _init(ks[1], (inner, 2 * h * n), dtype=dtype),
        "w_dt": _init(ks[2], (inner, h), scale=0.02, dtype=jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),               # per-head A
        "w_out_proj": _init(ks[4], (inner, d), dtype=dtype),
    }


def mamba_apply(p, cfg, x, *, cache=None):
    b, s, _ = x.shape
    h, dh, n = cfg.n_heads, cfg.ssm_head_dim, cfg.ssm_state
    xin, z = jnp.split(x @ p["w_in"], 2, axis=-1)          # (b,s,inner)
    bc = xin @ p["w_bc"]
    b_in, c_out = jnp.split(bc.reshape(b, s, h, 2 * n), 2, axis=-1)
    dt = jax.nn.softplus(xin.astype(jnp.float32) @ p["w_dt"])      # (b,s,h)
    a = jnp.exp(p["a_log"])                                # (h,) > 0
    log_decay = -dt * a                                    # (b,s,h)
    v = (xin.reshape(b, s, h, dh) * dt[..., None].astype(x.dtype))
    if s > 1:   # training or batched prefill (cache = carried-in state)
        o, h_final = chunked_linear_recurrence(
            c_out, b_in, v, log_decay, chunk=min(128, s), h0=cache,
            normalize=False)
    else:
        h0 = cache if cache is not None else \
            jnp.zeros((b, h, n, dh), jnp.float32)
        o, h_final = linear_recurrence_step(
            c_out[:, 0], b_in[:, 0], v[:, 0], log_decay[:, 0], h0,
            normalize=False)
        o = o[:, None]
    o = o.reshape(b, s, -1) * jax.nn.silu(z)
    return o @ p["w_out_proj"], h_final
