"""Activation/param sharding rules threaded through the models.

Models are pure functions; distribution is expressed as optional
``PartitionSpec`` constraints applied at the few points where GSPMD
propagation needs an anchor.  ``rules=None`` (smoke tests, single device)
makes every constraint a no-op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Compat shim: ``jax.shard_map`` graduated from
    ``jax.experimental.shard_map`` (and renamed ``check_rep`` →
    ``check_vma``) only in newer JAX; resolve whichever this install has.
    All shard_map'd layers (and the sharded tile-fusion executors) go
    through here.

    The replication-check keyword is threaded by *inspecting the resolved
    function's signature*, not by assuming which spelling goes with which
    import path: mid-migration JAX releases shipped the top-level
    ``jax.shard_map`` still taking ``check_rep``, and the experimental
    module later grew ``check_vma`` — pinning the keyword to the import
    path silently dropped the caller's flag on those versions.
    """
    import inspect

    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    kwargs = {}
    try:
        params = inspect.signature(sm).parameters
    except (TypeError, ValueError):          # builtins without signatures
        params = {}
    if "check_vma" in params:
        kwargs["check_vma"] = check_vma
    elif "check_rep" in params:
        kwargs["check_rep"] = check_vma
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **kwargs)


def mesh_row_repl_axes(mesh, layout: str = "1d") -> tuple:
    """Split a mesh's axis names into (row_axes, repl_axes, depth_axes)
    for the sharded tile-fusion executors.

    ``"1d"`` flattens every axis into the row-block dimension (repl and
    depth empty — the pre-2-D behavior for any mesh rank); ``"1.5d"``
    keeps the leading axis for row blocks and hands the trailing axes to
    the dense operand's column replicas; ``"2.5d"`` additionally peels the
    axes past the second into a depth dimension that replicates the
    wavefront-0 compute and splits wavefront-1 halo work.  Halo
    all-gathers run over ``row_axes`` only; depth layers combine their
    partial outputs with a psum over ``depth_axes``; the column-replica
    groups never exchange bytes — their column slices are independent by
    construction.  The split is derived from
    ``scheduler.resolve_mesh_layout`` — the one place the layout rule
    lives — so the executor's axis use can never disagree with the
    partitioner's shard counts; a 1-D mesh has nothing to replicate over,
    so every layout degenerates to (all axes, (), ())."""
    import numpy as np

    from ..core.tilefusion.scheduler import resolve_mesh_layout

    names = tuple(str(n) for n in mesh.axis_names)
    _, n_repl, n_depth = resolve_mesh_layout(np.shape(mesh.devices), layout)
    if n_depth > 1:
        return names[:1], names[1:2], names[2:]
    if n_repl > 1:
        return names[:1], names[1:], ()
    return names, (), ()


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis assignment.  ``batch_axes`` composes ("pod","data")."""
    batch_axes: tuple = ("data",)
    model_axis: str = "model"
    # whether attention heads divide the model axis (else heads replicate)
    shard_heads: bool = True
    # mesh handle for shard_map'd layers (MoE dispatch); None = single-device
    mesh: object = None

    @property
    def act_btd(self) -> P:   # (batch, seq, d_model)
        return P(self.batch_axes, None, None)

    @property
    def act_btf(self) -> P:   # (batch, seq, d_ff) — ffn hidden
        return P(self.batch_axes, None, self.model_axis)

    @property
    def act_bhtd(self) -> P:  # (batch, heads, seq, head_dim)
        # §Perf iteration 2: head_dim-sharding for non-divisible head counts
        # was REFUTED — it triggers SPMD involuntary full rematerialization
        # in the GQA QK dot (resharding storms).  Replicated-head attention
        # costs duplicate attention FLOPs on the model axis but removes the
        # TB-scale resharding collectives.
        if self.shard_heads:
            return P(self.batch_axes, self.model_axis, None, None)
        return P(self.batch_axes, None, None, None)

    @property
    def logits(self) -> P:    # (batch, seq, vocab)
        return P(self.batch_axes, None, self.model_axis)


def shard(x: jax.Array, spec: Optional[P]) -> jax.Array:
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# Parameter shardings: path-pattern -> PartitionSpec.  Matmul weights shard
# their contraction-free big axis over "model"; everything else replicates.
# Leading scan (layer-stack) axes are unsharded.
# --------------------------------------------------------------------------
_PARAM_RULES = [
    (r"embed", lambda nd: P(*([None] * (nd - 2) + ["model", None]))),   # (vocab, d)
    (r"(lm_head|w_out_proj)", lambda nd: P(*([None] * (nd - 2) + [None, "model"]))),
    # NOTE: sLSTM's w_rec is deliberately NOT here — it contracts inside the
    # per-timestep scan; sharding it would emit one all-reduce per timestep.
    (r"(wq|wk|wv|w_up|w_gate|w_in|w1|w3)$",
     lambda nd: P(*([None] * (nd - 2) + [None, "model"]))),
    (r"(wo|w_down|w2)$", lambda nd: P(*([None] * (nd - 2) + ["model", None]))),
    (r"(router|w_dkv|w_uk|w_uv|w_dq|w_uq)$", lambda nd: P()),
]


def param_spec(path: str, ndim: int) -> P:
    for pat, fn in _PARAM_RULES:
        if re.search(pat, path):
            if ndim >= 2:
                return fn(ndim)
            return P()
    return P()


def param_shardings(params, mesh) -> object:
    """Pytree of NamedSharding matching ``params`` (works on shape trees)."""
    def one(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        nd = len(leaf.shape)
        spec = param_spec(name, nd)
        # guard divisibility: replicate anything that doesn't divide
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dims = list(spec)
        ok = True
        for d, ax in enumerate(dims):
            if ax is None:
                continue
            sz = axis_sizes.get(ax, 1)
            if d < nd and leaf.shape[d] % sz != 0:
                ok = False
        if not ok:
            spec = P()
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)
