"""Shared model layers: norms, RoPE, attention (GQA/MLA), FFN, MoE.

Functional style: params are nested dicts of arrays; every layer is
``fn(params, x, ...) -> y``.  Layer stacks carry a leading scan axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding import ShardingRules, shard, shard_map


def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / (shape[0] ** 0.5)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------- norms ----
def rms_norm(g, x, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


# ----------------------------------------------------------------- rope ----
def rope_freqs(head_dim: int, max_pos: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_pos, dtype=jnp.float32)
    f = jnp.outer(t, inv)
    return jnp.cos(f), jnp.sin(f)


def apply_rope(x, pos):
    """x: (..., S, D); pos: (S,) or (B, S) int positions.  M-RoPE (qwen2-vl)
    degenerates to 1-D RoPE for the stubbed text-only backbone (DESIGN.md)."""
    d = x.shape[-1]
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = pos[..., :, None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    # broadcast over head axis: x (..., H, S, D) vs angles (..., S, D/2)
    if x.ndim == cos.ndim + 2:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ----------------------------------------------------- chunked attention ----
def chunked_attention(q, k, v, *, causal=True, window=0, chunk=1024,
                      q_offset=0):
    """Online-softmax attention, scanning kv chunks — the XLA twin of the
    Pallas flash kernel (memory O(S·chunk) instead of O(S^2)).

    q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) with H % Hkv == 0.
    q_offset: absolute position of q[0] (decode/prefill continuation).
    """
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = 1.0 / (d ** 0.5)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    q32 = q.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        kb = jnp.repeat(kb, rep, axis=1).astype(jnp.float32)
        vb = jnp.repeat(vb, rep, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kb) * scale
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] < sk  # padding
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l, acc), None

    init = (jnp.full((b, h, sq, 1), -1e30, jnp.float32),
            jnp.zeros((b, h, sq, 1), jnp.float32),
            jnp.zeros((b, h, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(
        step, init, (kc, vc, jnp.arange(n_chunks)))
    return (acc / jnp.where(l == 0, 1.0, l)).astype(q.dtype)


# ---------------------------------------------------------- GQA attention ----
def gqa_init(key, cfg, dtype):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, h * dh), dtype=dtype),
        "wk": _init(ks[1], (d, hkv * dh), dtype=dtype),
        "wv": _init(ks[2], (d, hkv * dh), dtype=dtype),
        "wo": _init(ks[3], (h * dh, d), dtype=dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def gqa_qkv(p, cfg, x, pos):
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"] + (p.get("bq", 0))
    k = x @ p["wk"] + (p.get("bk", 0))
    v = x @ p["wv"] + (p.get("bv", 0))
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.rope != "none":
        q = apply_rope(q, pos)
        k = apply_rope(k, pos)
    return q, k, v


def decode_attention(q, k_cache, v_cache, n_valid):
    """Single-token attention over a (possibly ring-buffer) KV cache.

    q: (B,H,1,dh); caches: (B,Hkv,C,dh); n_valid: valid slot count (traced).
    RoPE is applied at absolute positions *before* caching, so slot order is
    irrelevant — only validity masking matters (layers.py ring-buffer note).
    """
    b, h, _, dh = q.shape
    hkv, c = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    kf = jnp.repeat(k_cache, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v_cache, rep, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) / (dh ** 0.5)
    valid = jnp.arange(c)[None, None, None, :] < n_valid
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def gqa_attention(p, cfg, x, *, pos, rules: Optional[ShardingRules],
                  cache=None, cache_len=None, window: int = 0):
    """Self-attention; with ``cache=(k_cache, v_cache)`` runs decode (x is
    the new token), returning (out, new_cache).  When ``window > 0`` the
    cache is a ring buffer of ``window`` slots (long_500k feasibility)."""
    b, s, _ = x.shape
    q, k, v = gqa_qkv(p, cfg, x, pos)
    if rules is not None:
        q = shard(q, rules.act_bhtd)
        if not rules.shard_heads:
            # anchor k/v too: stops GSPMD propagating head_dim shardings
            # from the column-sharded wk/wv into the attention dots
            k = shard(k, rules.act_bhtd)
            v = shard(v, rules.act_bhtd)
    if cache is not None:
        k_cache, v_cache = cache
        c = k_cache.shape[2]
        if s > 1:
            # batched prefill from an empty cache (cache_len == 0): attend
            # over the fresh keys, then fill the cache slab.  For ring
            # buffers (window) with s >= c, key at absolute position p
            # lands at slot p % c — a roll of the last c keys.
            out = chunked_attention(q, k, v, causal=True, window=window)
            if s >= c:
                k_cache = jnp.roll(k[:, :, -c:], s % c, axis=2).astype(
                    k_cache.dtype)
                v_cache = jnp.roll(v[:, :, -c:], s % c, axis=2).astype(
                    v_cache.dtype)
            else:
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    k_cache, k.astype(k_cache.dtype), cache_len, axis=2)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    v_cache, v.astype(v_cache.dtype), cache_len, axis=2)
        else:
            slot = cache_len % c if window > 0 else cache_len
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), slot, axis=2)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), slot, axis=2)
            n_valid = jnp.minimum(cache_len + 1, c)
            out = decode_attention(q, k_cache, v_cache, n_valid)
        new_cache = (k_cache, v_cache)
    else:
        out = chunked_attention(q, k, v, causal=not cfg.is_encoder,
                                window=window)
        new_cache = None
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = out @ p["wo"]
    return out, new_cache


# ------------------------------------------------------------- MLA (MiniCPM3)
def mla_init(key, cfg, dtype):
    d, h, dh, r = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.mla_kv_rank
    ks = jax.random.split(key, 5)
    return {
        "wq": _init(ks[0], (d, h * dh), dtype=dtype),
        "w_dkv": _init(ks[1], (d, r), dtype=dtype),      # latent down-proj
        "w_uk": _init(ks[2], (r, h * dh), dtype=dtype),  # latent -> K
        "w_uv": _init(ks[3], (r, h * dh), dtype=dtype),  # latent -> V
        "wo": _init(ks[4], (h * dh, d), dtype=dtype),
    }


def mla_attention(p, cfg, x, *, pos, rules, cache=None, cache_len=None):
    """Multi-head latent attention: the KV cache stores the rank-r latent
    (the paper-style fused chain ``D = softmax(Q(K)ᵀ)·(latent·W_uv)`` keeps
    the expanded K/V as tile-local intermediates)."""
    b, s, _ = x.shape
    h, dh, r = cfg.n_heads, cfg.head_dim, cfg.mla_kv_rank
    q = (x @ p["wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    lat = x @ p["w_dkv"]                                   # (b, s, r)
    if cfg.rope != "none":
        q = apply_rope(q, pos)
    if cache is not None:
        lat_cache = jax.lax.dynamic_update_slice_in_dim(
            cache, lat.astype(cache.dtype), cache_len, axis=1)
        if s > 1:   # batched prefill (cache_len == 0)
            k = (lat @ p["w_uk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            v = (lat @ p["w_uv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            if cfg.rope != "none":
                k = apply_rope(k, pos)
            out = chunked_attention(q, k, v, causal=True)
            return (out.transpose(0, 2, 1, 3).reshape(b, s, -1) @ p["wo"],
                    lat_cache)
        sk = lat_cache.shape[1]
        k = (lat_cache @ p["w_uk"]).reshape(b, sk, h, dh).transpose(0, 2, 1, 3)
        v = (lat_cache @ p["w_uv"]).reshape(b, sk, h, dh).transpose(0, 2, 1, 3)
        if cfg.rope != "none":
            k = apply_rope(k, jnp.arange(sk))
        out = decode_attention(q, k, v, jnp.minimum(cache_len + 1, sk))
    else:
        lat_cache = None
        k = (lat @ p["w_uk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        v = (lat @ p["w_uv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        if cfg.rope != "none":
            k = apply_rope(k, pos)
        out = chunked_attention(q, k, v, causal=True)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1) @ p["wo"]
    return out, lat_cache


# ------------------------------------------------------- cross-attention ----
def cross_attention(p, cfg, x, enc_out, *, rules):
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    se = enc_out.shape[1]
    q = (x @ p["wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (enc_out @ p["wk"]).reshape(b, se, -1, dh).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"]).reshape(b, se, -1, dh).transpose(0, 2, 1, 3)
    out = chunked_attention(q, k, v, causal=False)
    return out.transpose(0, 2, 1, 3).reshape(b, s, -1) @ p["wo"], None


# ------------------------------------------------------------------- FFN ----
def ffn_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init(ks[0], (d, f), dtype=dtype),
        "w_up": _init(ks[1], (d, f), dtype=dtype),
        "w_down": _init(ks[2], (f, d), dtype=dtype),
    }


def ffn_apply(p, cfg, x, rules: Optional[ShardingRules]):
    """Gated FFN (SwiGLU/GeGLU).  This is the dense limiting case of tile
    fusion — on TPU it lowers to kernels/fused_ffn keeping h in VMEM."""
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    if rules is not None:
        h = shard(h, rules.act_btf)
    return h @ p["w_down"]


# ------------------------------------------------------------------- MoE ----
def moe_init(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": _init(ks[0], (d, e), scale=0.02, dtype=jnp.float32),
        "w1": _init(ks[1], (e, d, f), dtype=dtype),       # gate proj
        "w3": _init(ks[2], (e, d, f), dtype=dtype),       # up proj
        "w2": _init(ks[3], (e, f, d), dtype=dtype),       # down proj
    }
    if cfg.moe_shared_expert:
        p["shared"] = ffn_init(jax.random.fold_in(key, 7), cfg, dtype)
    return p


def _row_dispatch(cfg, xf, router, cap):
    """Capacity dispatch for ONE token row (s, d) -> (xe, combine-aux).

    All sort/gather/scatter indices stay within the row — local to whatever
    shard holds the row."""
    s, d = xf.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    logits = xf.astype(jnp.float32) @ router               # (s, e)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)                 # (s, k)
    top_g = top_g / jnp.clip(top_g.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                             # (s*k,)
    flat_t = jnp.repeat(jnp.arange(s), k)
    flat_g = top_g.reshape(-1)
    order = jnp.argsort(flat_e)
    se_, st_, sg_ = flat_e[order], flat_t[order], flat_g[order]
    pos_in_e = jnp.arange(se_.shape[0]) - jnp.searchsorted(
        se_, jnp.arange(e))[se_]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se_ * cap + pos_in_e, e * cap)  # overflow -> drop
    gathered = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(xf[st_])
    xe = gathered[:-1].reshape(e, cap, d)
    return xe, (keep, slot, st_, sg_)


def _row_combine(ye, aux, s, d, dtype):
    keep, slot, st_, sg_ = aux
    e_cap = ye.shape[0] * ye.shape[1]
    yf = ye.reshape(e_cap, d)
    contrib = jnp.where(keep[:, None], yf[jnp.clip(slot, 0, e_cap - 1)]
                        * sg_[:, None].astype(dtype), 0)
    return jnp.zeros((s, d), dtype).at[st_].add(contrib)


def _expert_ffn(cfg, xe, w1, w3, w2):
    """The fused two-matmul expert chain (tile fusion's dense instance —
    kernels/moe.py on TPU keeps h in VMEM)."""
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", xe, w1)) * \
        jnp.einsum("ecd,edf->ecf", xe, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def moe_apply(p, cfg, x, rules: Optional[ShardingRules],
              capacity_factor: float = 1.25):
    """Top-k MoE: capacity-based sorted dispatch per batch row.

    Tile-fusion mapping (DESIGN.md §4): the dispatch one-hot is the sparse A;
    tokens of one expert form a fused tile; gather (wavefront-0 producer) →
    two expert matmuls with the intermediate kept local → scatter (the single
    barrier).

    §Perf iterations 1+3 (beyond-paper): dispatch is per batch row (a global
    argsort over the data-sharded token axis lowered to TB-scale
    collectives), and under a mesh the whole layer runs in shard_map —
    dispatch scatter/gather stay device-local (GSPMD all-gathered the
    (b, e·cap, d) scatter operand otherwise) and the expert contraction is
    Megatron-style f-sharded with ONE psum of (b_local, s, d) per layer.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    cap = int(capacity_factor * s * k / e)
    cap = max(8, -(-cap // 8) * 8)

    def local_moe(router, w1, w3, w2, shared, xl):
        def row(xf):
            xe, aux = _row_dispatch(cfg, xf, router, cap)
            ye = _expert_ffn(cfg, xe, w1, w3, w2)   # f-sliced under shard_map
            return _row_combine(ye, aux, s, d, xl.dtype)
        y = jax.vmap(row)(xl)
        if shared is not None:
            act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
            h = act(xl @ shared["w_gate"]) * (xl @ shared["w_up"])
            y = y + h @ shared["w_down"]
        return y

    shared = p.get("shared")
    n_batch_shards = 1
    if rules is not None and rules.mesh is not None:
        sizes = dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape))
        for ax in rules.batch_axes:
            n_batch_shards *= sizes.get(ax, 1)
    if rules is None or rules.mesh is None or b % n_batch_shards != 0:
        # single-device path, or batch (e.g. long_500k b=1) not divisible by
        # the data axes — tiny dispatch, GSPMD handles it
        return local_moe(p["router"], p["w1"], p["w3"], p["w2"], shared, x)

    from jax.sharding import PartitionSpec as P
    ba, mx = rules.batch_axes, rules.model_axis
    shared_spec = None if shared is None else {
        "w_gate": P(None, mx), "w_up": P(None, mx), "w_down": P(mx, None)}
    f = shard_map(
        lambda router, w1, w3, w2, sh, xl: jax.lax.psum(
            local_moe(router, w1, w3, w2, sh, xl), mx),
        mesh=rules.mesh,
        in_specs=(P(), P(None, None, mx), P(None, None, mx),
                  P(None, mx, None), shared_spec, P(ba, None, None)),
        out_specs=P(ba, None, None),
        check_vma=False,
    )
    return f(p["router"], p["w1"], p["w3"], p["w2"], shared, x)


# ------------------------------------------------------------- embedding ----
def embed_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "embed": _init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02,
                       dtype=dtype),
        "lm_head": _init(ks[1], (cfg.d_model, cfg.vocab_size), dtype=dtype),
    }
