"""Hetero-GCN — RGCN-style relational convolution on the fused stack.

One layer computes, per destination node type ``dt``,

    ``out[dt] = σ( Σ_{r : dst(r) = dt}  Â_r · (X[src(r)] · W_r) )``

— one normalized-adjacency GeMM-SpMM per relation, summed over the
relations that share a destination type.  The whole bundle of
per-relation products runs as ONE ``hetero_fused_matmul`` dispatch
(block-diagonal stack, single Algorithm-1 inspection, single kernel
launch) instead of the N small SpMMs an HGT/RGCN loop would issue; the
per-relation outputs come back un-stacked and are summed per type.

The layer is functional in its parameters (a dict of per-relation
weight matrices) so ``jax.grad`` flows through the fused custom_vjp.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sparse.formats import CSR
from ..core.tilefusion import api, hetero
from .gcn import normalize_adjacency


@dataclasses.dataclass(frozen=True)
class HeteroGraph:
    """A typed multi-relation graph.

    ``relations`` maps ``(src_type, name, dst_type)`` to the relation's
    adjacency (``(n_dst, n_src)`` CSR); ``node_counts`` gives each node
    type's cardinality.  Relation order is the sorted key order — the
    deterministic stacking order of the fused dispatch."""

    node_counts: dict
    relations: dict

    def __post_init__(self):
        for (src, _, dst), a in self.relations.items():
            if a.n_rows != self.node_counts[dst]:
                raise ValueError(f"adjacency of {(src, _, dst)} has "
                                 f"{a.n_rows} rows; dst type {dst!r} has "
                                 f"{self.node_counts[dst]} nodes")
            if a.n_cols != self.node_counts[src]:
                raise ValueError(f"adjacency of {(src, _, dst)} has "
                                 f"{a.n_cols} cols; src type {src!r} has "
                                 f"{self.node_counts[src]} nodes")

    @property
    def rel_keys(self):
        return sorted(self.relations)


class HeteroGCNLayer:
    """One relational convolution layer on the fused hetero dispatch."""

    def __init__(self, graph: HeteroGraph, in_dims: dict, out_dim: int, *,
                 spec: api.FusionSpec | None = None, backend: str = "auto",
                 activation=jax.nn.relu):
        self.graph = graph
        self.in_dims = dict(in_dims)
        self.out_dim = int(out_dim)
        self.spec = spec if spec is not None else api.FusionSpec()
        self.backend = backend
        self.activation = activation
        # symmetric-normalized adjacencies, fixed stacking order
        self.rel_keys = graph.rel_keys
        self.adjs = {k: normalize_adjacency(graph.relations[k])
                     for k in self.rel_keys}
        # warm the one stacked schedule (and its cache entry) up front —
        # the hetero analogue of GCN.__init__'s per-layer warmup
        stack = hetero.stack_adjacencies([self.adjs[k]
                                          for k in self.rel_keys])
        b_col = sum(self.in_dims[k[0]] for k in self.rel_keys)
        self.entry = api.get_schedule(stack.a, b_col=b_col,
                                      c_col=self.out_dim, spec=self.spec)

    def init_params(self, rng: np.random.Generator) -> dict:
        """Glorot-ish per-relation weights ``W_r`` of shape
        ``(in_dims[src(r)], out_dim)``."""
        params = {}
        for key in self.rel_keys:
            fan_in = self.in_dims[key[0]]
            scale = float(np.sqrt(2.0 / (fan_in + self.out_dim)))
            params[key] = jnp.asarray(
                rng.standard_normal((fan_in, self.out_dim)) * scale,
                jnp.float32)
        return params

    def __call__(self, params: dict, feats: dict) -> dict:
        """``feats`` maps node type -> ``(n_type, in_dims[type])`` array;
        returns per-destination-type activations."""
        relations = [(self.adjs[k], feats[k[0]], params[k])
                     for k in self.rel_keys]
        outs = hetero.hetero_fused_matmul(relations, backend=self.backend,
                                          spec=self.spec)
        by_dst: dict = {}
        for key, d_r in zip(self.rel_keys, outs):
            dst = key[2]
            by_dst[dst] = d_r if dst not in by_dst else by_dst[dst] + d_r
        if self.activation is not None:
            by_dst = {t: self.activation(v) for t, v in by_dst.items()}
        return by_dst

    def reference(self, params: dict, feats: dict) -> dict:
        """The per-relation loop oracle (unfused dispatch per relation) —
        what the fused layer must reproduce exactly."""
        by_dst: dict = {}
        for key in self.rel_keys:
            d_r = api.tile_fused_matmul(self.adjs[key], feats[key[0]],
                                        params[key], backend="unfused",
                                        spec=self.spec)
            dst = key[2]
            by_dst[dst] = d_r if dst not in by_dst else by_dst[dst] + d_r
        if self.activation is not None:
            by_dst = {t: self.activation(v) for t, v in by_dst.items()}
        return by_dst
