"""GCN — the paper's native application, built on tile fusion.

One GCN layer is ``H' = σ(Â (H W))`` — exactly the paper's GeMM-SpMM with
``A = Â`` (normalized adjacency), ``B = H``, ``C = W``.  Every layer routes
through ``core.tilefusion.api.tile_fused_matmul``: the schedule is inspected
once per (graph, layer shape) and served from the content-keyed cache for
every subsequent layer and training step (paper §4.2.3 amortization).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sparse.formats import CSR
from ..core.tilefusion import api


def normalize_adjacency(a: CSR) -> CSR:
    """Â = D^{-1/2} (A) D^{-1/2} (self-loops assumed already present).

    The degree arithmetic runs in float64 for accuracy, but the result is
    cast back to ``a.data``'s dtype: a float32 (or bf16) adjacency must
    not silently become a float64 one, which would hash, pack, and price
    every downstream schedule at the wrong itemsize.

    Square adjacencies use the row degree on both sides (the classic
    symmetric normalization).  Rectangular ones — hetero-graph relations
    are ``(n_dst, n_src)`` — scale each side by its own axis degree:
    rows by out-neighbour count, columns by in-neighbour count."""
    deg = np.maximum(np.diff(a.indptr), 1).astype(np.float64)
    dinv = 1.0 / np.sqrt(deg)
    rows = np.repeat(np.arange(a.n_rows), np.diff(a.indptr))
    if a.n_rows == a.n_cols:
        cinv = dinv
    else:
        col_deg = np.maximum(
            np.bincount(a.indices, minlength=a.n_cols), 1).astype(
                np.float64)
        cinv = 1.0 / np.sqrt(col_deg)
    data = (a.data * dinv[rows] * cinv[a.indices]).astype(
        a.data.dtype, copy=False)
    return CSR(a.n_rows, a.n_cols, a.indptr, a.indices, data)


class GCN:
    """Tile-fused GCN on the unified dispatch API."""

    def __init__(self, cfg, adj: CSR, *, p: int = 8,
                 cache_size: float = 600_000.0, ct_size: int = 2048,
                 spec: api.FusionSpec | None = None):
        self.cfg = cfg
        self.adj = normalize_adjacency(adj)
        # one FusionSpec drives every layer's inspection and dispatch; the
        # scalar ctor knobs survive as sugar for the common case
        self.spec = spec if spec is not None else api.FusionSpec(
            p=p, cache_size=cache_size, ct_size=ct_size)
        self.p = self.spec.p
        self.cache_size = self.spec.cache_size
        self.ct_size = self.spec.ct_size
        # warm the inspector cache for every layer shape once per graph;
        # forward() then hits it for every layer and step
        dims = ([cfg.in_dim] + [cfg.hidden_dim] * (cfg.n_layers - 1)
                + [cfg.out_dim])
        self.dims = dims
        self.entries = [
            api.get_schedule(self.adj, b_col=dims[i], c_col=dims[i + 1],
                             spec=self.spec)
            for i in range(cfg.n_layers)]
        self.entry = self.entries[0]   # back-compat alias (layer 0)

    @property
    def sched(self):
        return self.entry.sched

    @property
    def dsched(self):
        return self.entry.dsched

    def layer_traffic_models(self) -> list:
        """Per-layer Eq-3 traffic models from the warmed entries — one dict
        per layer, not just layer 0 (the layers have different ``b_col`` /
        ``c_col`` and hence different fused savings)."""
        return [e.traffic_model for e in self.entries]

    def train_step_traffic_models(self) -> list:
        """Per-layer forward+backward traffic (``cost_model
        .train_step_traffic``): the transpose entry prices the backward's
        fused product against Âᵀ, the extra SpMM term its ``Âᵀ·Ḋ``."""
        import dataclasses

        from ..core.tilefusion import cost_model
        out = []
        for e in self.entries:
            et = api.get_schedule(
                self.adj, b_col=e.c_col, c_col=e.b_col,
                spec=dataclasses.replace(self.spec, transpose=True,
                                         dtype_bytes=e.dtype_bytes))
            out.append(cost_model.train_step_traffic(
                e.traffic_model, et.traffic_model, nnz=self.adj.nnz,
                n_i=self.adj.n_cols, n_j=self.adj.n_rows, c_col=e.c_col,
                dtype_bytes=e.dtype_bytes))
        return out

    def init_params(self, key):
        cfg = self.cfg
        dims = ([cfg.in_dim] + [cfg.hidden_dim] * (cfg.n_layers - 1)
                + [cfg.out_dim])
        ks = jax.random.split(key, cfg.n_layers)
        return [
            jax.random.normal(ks[i], (dims[i], dims[i + 1]), jnp.float32)
            / (dims[i] ** 0.5)
            for i in range(cfg.n_layers)
        ]

    def forward(self, params, x, *, fused: bool = True, impl: str = None,
                backend: str = None, mesh=None):
        """``backend=`` overrides directly; otherwise the legacy
        (fused, impl) pair maps onto the API's explicit backends.
        Differentiable end to end: under ``jax.grad`` each layer's
        backward runs the fused transposed products (api custom_vjp),
        including under a non-trivial ``mesh=``."""
        import dataclasses
        be = backend or ("unfused" if not fused
                         else "pallas" if impl == "pallas" else "xla")
        spec = (dataclasses.replace(self.spec, mesh=mesh)
                if mesh is not None else self.spec)
        for i, w in enumerate(params):
            h = api.tile_fused_matmul(self.adj, x, w, backend=be, spec=spec)
            x = jax.nn.relu(h) if i < len(params) - 1 else h
        return x

    def loss(self, params, x, labels, *, fused: bool = True,
             backend: str = None, mesh=None):
        logits = self.forward(params, x, fused=fused, backend=backend,
                              mesh=mesh)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
