"""GCN — the paper's native application, built on tile fusion.

One GCN layer is ``H' = σ(Â (H W))`` — exactly the paper's GeMM-SpMM with
``A = Â`` (normalized adjacency), ``B = H``, ``C = W``.  The layer executes
through the fused schedule (core/tilefusion), so GNN training in this
framework *is* the paper's workload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sparse.formats import CSR
from ..core.tilefusion import (build_schedule, fused_ops, to_device_schedule)


def normalize_adjacency(a: CSR) -> CSR:
    """Â = D^{-1/2} (A) D^{-1/2} (self-loops assumed already present)."""
    deg = np.maximum(np.diff(a.indptr), 1).astype(np.float64)
    dinv = 1.0 / np.sqrt(deg)
    rows = np.repeat(np.arange(a.n_rows), np.diff(a.indptr))
    data = a.data * dinv[rows] * dinv[a.indices]
    return CSR(a.n_rows, a.n_cols, a.indptr, a.indices, data)


class GCN:
    """Tile-fused GCN.  The schedule is built once per graph and reused for
    every layer and every training step (paper §4.2.3 amortization)."""

    def __init__(self, cfg, adj: CSR, *, p: int = 8,
                 cache_size: float = 600_000.0, ct_size: int = 2048):
        self.cfg = cfg
        self.adj = normalize_adjacency(adj)
        # uniform split: zero-padding fused executor + 1:1 Pallas grid map
        self.sched = build_schedule(self.adj, b_col=cfg.hidden_dim,
                                    c_col=cfg.hidden_dim, p=p,
                                    cache_size=cache_size, ct_size=ct_size,
                                    uniform_split=True)
        self.dsched = to_device_schedule(self.adj, self.sched)
        self.ell = fused_ops.csr_to_ell(self.adj)

    def init_params(self, key):
        cfg = self.cfg
        dims = ([cfg.in_dim] + [cfg.hidden_dim] * (cfg.n_layers - 1)
                + [cfg.out_dim])
        ks = jax.random.split(key, cfg.n_layers)
        return [
            jax.random.normal(ks[i], (dims[i], dims[i + 1]), jnp.float32)
            / (dims[i] ** 0.5)
            for i in range(cfg.n_layers)
        ]

    def forward(self, params, x, *, fused: bool = True, impl: str = "xla"):
        for i, w in enumerate(params):
            if fused and impl == "pallas":
                h = self._layer_pallas(x, w)
            elif fused:
                h = fused_ops.fused_gemm_spmm(self.dsched, x, w)
            else:
                h = fused_ops.unfused_gemm_spmm(*self.ell, x, w)
            x = jax.nn.relu(h) if i < len(params) - 1 else h
        return x

    def _layer_pallas(self, x, w):
        """One GCN layer through the Pallas tile-fusion kernel (requires a
        uniform schedule; interpret mode on CPU, compiled on TPU)."""
        from ..kernels import ops as kops
        ds = self.dsched
        t, n_t = ds.t_pad, ds.n_tiles0
        assert x.shape[0] == ds.n_i
        x_pad = jnp.pad(x, ((0, n_t * t - x.shape[0]), (0, 0)))
        # wavefront 0: fused GeMM + in-tile SpMM rows on the MXU
        d1, rows0 = kops.tile_fused_gemm_spmm_wf0(
            jnp.asarray(ds.ell_cols0), jnp.asarray(ds.ell_vals0, x.dtype),
            x_pad, w, t=t)
        c_col = w.shape[1]
        d = jnp.zeros((ds.n_j, c_col), x.dtype).at[
            ds.j_rows0.reshape(-1)].set(rows0.reshape(-1, c_col),
                                        mode="drop")
        # barrier = kernel boundary; wavefront 1 over the (spilled) D1
        if ds.j_rows1.size:
            t1, j1, w1 = ds.ell_cols1.shape
            rows1 = kops.spmm_ell(
                jnp.asarray(ds.ell_cols1.reshape(t1 * j1, w1)),
                jnp.asarray(ds.ell_vals1.reshape(t1 * j1, w1), x.dtype),
                d1[: ds.n_i], impl="xla" if (t1 * j1) % 256 else "pallas")
            d = d.at[ds.j_rows1.reshape(-1)].set(rows1, mode="drop")
        return d

    def loss(self, params, x, labels, *, fused: bool = True):
        logits = self.forward(params, x, fused=fused)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
