"""Unified LM: decoder-only / enc-dec / MoE / SSM / hybrid, scan-stacked.

Layers are stacked with a leading scan axis so HLO size and compile time are
O(1) in depth — required for the 80-layer dry-run cells.  Block flavour is
selected by ``cfg.block_pattern``:

  attn          — self-attention + FFN/MoE          (dense, moe, vlm, enc-dec)
  mlstm7+slstm  — xLSTM groups: 7 mLSTM + 1 sLSTM   (xlstm-1.3b)
  attn+mamba    — parallel attention & mamba heads  (hymba-1.5b)
  sparse-band   — banded-decay SpMM token mixer on the tile-fusion seam
                  (train/prefill; ``ssm.band_mix_apply`` routes the mix
                  through ``tile_fused_matmul``'s custom_vjp)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as S
from .sharding import ShardingRules, shard


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _unroll(cfg, n=None):
    """Scan unroll factor — full for dry-run FLOP accounting (base.py)."""
    return (n if n is not None else cfg.n_layers) if cfg.scan_unroll else 1


def _maybe_remat(cfg, fn):
    """remat policy: none | full | dots (save matmul outputs — §Perf iter 4:
    trades activation memory for no-matmul-recompute in backward)."""
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


# ============================================================== init =======
def _attn_block_init(key, cfg, dtype, cross: bool = False):
    ks = jax.random.split(key, 6)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype),
         "ln2": jnp.ones((cfg.d_model,), dtype)}
    p["attn"] = L.mla_init(ks[0], cfg, dtype) if cfg.mla \
        else L.gqa_init(ks[0], cfg, dtype)
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
        p["xattn"] = L.gqa_init(ks[1], cfg, dtype)
    if cfg.n_experts:
        p["moe"] = L.moe_init(ks[2], cfg, dtype)
    else:
        p["ffn"] = L.ffn_init(ks[2], cfg, dtype)
    return p


def _sparse_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mix": S.band_mix_init(ks[0], cfg, dtype),
        "ffn": L.ffn_init(ks[1], cfg, dtype),
    }


def _hybrid_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": L.gqa_init(ks[0], cfg, dtype),
        "mamba": S.mamba_init(ks[1], cfg, dtype),
        "ffn": L.ffn_init(ks[2], cfg, dtype),
    }


def _stack_init(fn, key, n, *args):
    keys = jax.random.split(key, max(n, 1))
    return jax.vmap(lambda k: fn(k, *args))(keys)


def init_params(cfg, key):
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 5)
    params = {"tok": L.embed_init(ks[0], cfg, dtype),
              "ln_f": jnp.ones((cfg.d_model,), dtype)}
    if cfg.frontend != "none":
        # stubbed modality frontend: precomputed frame/patch embeddings are
        # projected into d_model (input_specs provides the embeddings).
        params["frontend_proj"] = L._init(
            jax.random.fold_in(key, 9), (cfg.d_model, cfg.d_model), dtype=dtype)
    if cfg.block_pattern == "mlstm7+slstm":
        assert cfg.n_layers % 8 == 0, "xLSTM pattern needs n_layers % 8 == 0"
        g = cfg.n_layers // 8
        keys = jax.random.split(ks[1], g)
        params["mlstm"] = jax.vmap(
            lambda k: _stack_init(S.mlstm_init, k, 7, cfg, dtype))(keys)
        params["slstm"] = _stack_init(S.slstm_init, ks[2], g, cfg, dtype)
        params["ln_m"] = jnp.ones((g, 7, cfg.d_model), dtype)
        params["ln_s"] = jnp.ones((g, cfg.d_model), dtype)
    elif cfg.block_pattern == "attn+mamba":
        params["layers"] = _stack_init(
            _hybrid_block_init, ks[1], cfg.n_layers, cfg, dtype)
    elif cfg.block_pattern == "sparse-band":
        params["layers"] = _stack_init(
            _sparse_block_init, ks[1], cfg.n_layers, cfg, dtype)
    else:
        cross = cfg.encoder_layers > 0
        params["layers"] = _stack_init(
            lambda k, c, d: _attn_block_init(k, c, d, cross=cross),
            ks[1], cfg.n_layers, cfg, dtype)
        if cfg.encoder_layers:
            enc_cfg = cfg
            params["enc_layers"] = _stack_init(
                lambda k, c, d: _attn_block_init(k, c, d, cross=False),
                ks[3], cfg.encoder_layers, cfg, dtype)
            params["ln_enc"] = jnp.ones((cfg.d_model,), dtype)
    return params


# ============================================================ forward ======
def _mixer(lp, cfg, x, pos, rules, cache=None, cache_len=None):
    """The sequence mixer of an attn-family block."""
    if cfg.mla:
        return L.mla_attention(lp["attn"], cfg, x, pos=pos, rules=rules,
                               cache=cache, cache_len=cache_len)
    return L.gqa_attention(lp["attn"], cfg, x, pos=pos, rules=rules,
                           cache=cache, cache_len=cache_len,
                           window=cfg.window)


def _channel(lp, cfg, x, rules):
    if cfg.n_experts:
        return L.moe_apply(lp["moe"], cfg, x, rules)
    return L.ffn_apply(lp["ffn"], cfg, x, rules)


def _attn_block(cfg, rules, pos, enc_out, x, lp,
                cache=None, cache_len=None):
    h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    a, new_cache = _mixer(lp, cfg, h, pos, rules, cache, cache_len)
    x = x + a
    if enc_out is not None:
        h = L.rms_norm(lp["ln_x"], x, cfg.norm_eps)
        a, _ = L.cross_attention(lp["xattn"], cfg, h, enc_out, rules=rules)
        x = x + a
    h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = x + _channel(lp, cfg, h, rules)
    if rules is not None:
        x = shard(x, rules.act_btd)
    return x, new_cache


def _sparse_band_block(cfg, rules, a_band, x, lp):
    """Token mixer = banded-decay SpMM through the fused seam.  No decode
    cache: the band operator needs the full (pre-)fill window, so this
    pattern serves training and prefill shapes only."""
    h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    x = x + S.band_mix_apply(lp["mix"], cfg, h, a_band)
    h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = x + L.ffn_apply(lp["ffn"], cfg, h, rules)
    if rules is not None:
        x = shard(x, rules.act_btd)
    return x


def _hybrid_block(cfg, rules, pos, x, lp, cache=None, cache_len=None):
    h = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    a, kv_cache = L.gqa_attention(lp["attn"], cfg, h, pos=pos, rules=rules,
                                  cache=None if cache is None else cache[:2],
                                  cache_len=cache_len, window=cfg.window)
    m, ssm_state = S.mamba_apply(lp["mamba"], cfg, h,
                                 cache=None if cache is None else cache[2])
    x = x + (a + m) * 0.5                      # parallel heads, averaged
    h = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    x = x + L.ffn_apply(lp["ffn"], cfg, h, rules)
    if rules is not None:
        x = shard(x, rules.act_btd)
    new_cache = None if cache is None else (*kv_cache, ssm_state)
    return x, new_cache


def _embed_inputs(cfg, params, batch, rules):
    dtype = _dtype(cfg)
    if "tokens" in batch:
        x = params["tok"]["embed"][batch["tokens"]]
    else:  # stubbed modality frontend: precomputed embeddings
        x = batch["embeds"].astype(dtype) @ params["frontend_proj"]
    if rules is not None:
        x = shard(x, rules.act_btd)
    return x


def _encoder(cfg, params, enc_embeds, rules):
    import dataclasses
    x = enc_embeds.astype(_dtype(cfg)) @ params["frontend_proj"]
    pos = jnp.arange(x.shape[1])
    enc_cfg = dataclasses.replace(cfg, is_encoder=True, mla=False,
                                  n_experts=0, window=0)
    base_block = functools.partial(_attn_block, enc_cfg, rules, pos, None)
    block = _maybe_remat(cfg, base_block)

    def f(c, lp):
        y, _ = block(c, lp)
        return y, None

    x, _ = jax.lax.scan(f, x, params["enc_layers"],
                        unroll=_unroll(cfg, cfg.encoder_layers))
    return L.rms_norm(params["ln_enc"], x, cfg.norm_eps)


def forward(cfg, params, batch, *, rules: Optional[ShardingRules] = None):
    """batch: {"tokens" | "embeds", ["enc_embeds"]} -> logits (B, S, V)."""
    x = _embed_inputs(cfg, params, batch, rules)
    pos = jnp.arange(x.shape[1])
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encoder(cfg, params, batch["enc_embeds"], rules)

    if cfg.block_pattern == "mlstm7+slstm":
        def group(c, gp):
            def mblock(c2, lp):
                ln, bp = lp
                h = L.rms_norm(ln, c2, cfg.norm_eps)
                o, _ = S.mlstm_apply(bp, cfg, h)
                return c2 + o, None
            mb = _maybe_remat(cfg, mblock)
            c, _ = jax.lax.scan(mb, c, (gp["ln_m"], gp["mlstm"]),
                                unroll=_unroll(cfg, 7))
            h = L.rms_norm(gp["ln_s"], c, cfg.norm_eps)
            o, _ = S.slstm_apply(gp["slstm"], cfg, h)
            return c + o, None
        x, _ = jax.lax.scan(group, x, {
            "mlstm": params["mlstm"], "slstm": params["slstm"],
            "ln_m": params["ln_m"], "ln_s": params["ln_s"]},
            unroll=_unroll(cfg, cfg.n_layers // 8))
    elif cfg.block_pattern == "attn+mamba":
        def f(c, lp):
            y, _ = _hybrid_block(cfg, rules, pos, c, lp)
            return y, None
        fb = _maybe_remat(cfg, f)
        x, _ = jax.lax.scan(fb, x, params["layers"], unroll=_unroll(cfg))
    elif cfg.block_pattern == "sparse-band":
        a_band = S.decay_band_csr(x.shape[1], cfg.band_window, cfg.band_decay)

        def f(c, lp):
            return _sparse_band_block(cfg, rules, a_band, c, lp), None
        fb = _maybe_remat(cfg, f)
        x, _ = jax.lax.scan(fb, x, params["layers"], unroll=_unroll(cfg))
    else:
        def f(c, lp):
            y, _ = _attn_block(cfg, rules, pos, enc_out, c, lp)
            return y, None
        fb = _maybe_remat(cfg, f)
        x, _ = jax.lax.scan(fb, x, params["layers"], unroll=_unroll(cfg))

    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = x @ params["tok"]["lm_head"]
    if rules is not None:
        logits = shard(logits, rules.logits)
    return logits


# ============================================================= decode ======
def init_cache(cfg, batch_size: int, max_len: int):
    """KV/state caches, leading layer axis, ready for lax.scan."""
    dtype = _dtype(cfg)
    if cfg.block_pattern == "sparse-band":
        raise NotImplementedError(
            "sparse-band blocks have no decode cache; serve via forward()")
    lcount = cfg.n_layers
    c = min(max_len, cfg.window) if cfg.window > 0 else max_len
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if cfg.block_pattern == "mlstm7+slstm":
        g = cfg.n_layers // 8
        h, sdh = cfg.n_heads, cfg.ssm_head_dim
        inner = h * sdh
        return {
            "mlstm": jnp.zeros((g, 7, batch_size, h, sdh, sdh + 1), jnp.float32),
            "slstm": (jnp.zeros((g, batch_size, inner), jnp.float32),
                      jnp.zeros((g, batch_size, inner), jnp.float32)),
        }
    if cfg.block_pattern == "attn+mamba":
        return (
            jnp.zeros((lcount, batch_size, hkv, c, dh), dtype),
            jnp.zeros((lcount, batch_size, hkv, c, dh), dtype),
            jnp.zeros((lcount, batch_size, cfg.n_heads, cfg.ssm_state,
                       cfg.ssm_head_dim), jnp.float32),
        )
    if cfg.mla:
        return jnp.zeros((lcount, batch_size, max_len, cfg.mla_kv_rank), dtype)
    return (
        jnp.zeros((lcount, batch_size, hkv, c, dh), dtype),
        jnp.zeros((lcount, batch_size, hkv, c, dh), dtype),
    )


def decode_step(cfg, params, batch, cache, cache_len,
                *, rules: Optional[ShardingRules] = None):
    """One decode step — or a batched PREFILL when given S > 1 tokens.

    batch: {"tokens": (B,S)} (or {"embeds": (B,S,d)}); S == 1 is the decode
    step; S > 1 runs a batched prefill that fills the caches (requires
    cache_len == 0 for attention caches).
    cache_len: scalar int32 — tokens already in the cache.
    Returns (logits (B,S,V), new_cache).
    """
    if cfg.block_pattern == "sparse-band":
        raise NotImplementedError(
            "sparse-band blocks have no decode cache; serve via forward()")
    x = _embed_inputs(cfg, params, batch, rules)
    s = x.shape[1]
    pos = cache_len + jnp.arange(s, dtype=jnp.int32)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encoder(cfg, params, batch["enc_embeds"], rules)

    if cfg.block_pattern == "mlstm7+slstm":
        def group(c, xs):
            gp, gc = xs
            def mstep(c2, xs2):
                (ln, bp), st = xs2
                h = L.rms_norm(ln, c2, cfg.norm_eps)
                o, st2 = S.mlstm_apply(bp, cfg, h, cache=st)
                return c2 + o, st2
            c, mst = jax.lax.scan(
                mstep, c, ((gp["ln_m"], gp["mlstm"]), gc["mlstm"]),
                unroll=_unroll(cfg, 7))
            h = L.rms_norm(gp["ln_s"], c, cfg.norm_eps)
            o, sst = S.slstm_apply(gp["slstm"], cfg, h, cache=gc["slstm"])
            return c + o, {"mlstm": mst, "slstm": sst}
        x, new_cache = jax.lax.scan(group, x, (
            {"mlstm": params["mlstm"], "slstm": params["slstm"],
             "ln_m": params["ln_m"], "ln_s": params["ln_s"]}, cache),
            unroll=_unroll(cfg, cfg.n_layers // 8))
    elif cfg.block_pattern == "attn+mamba":
        def f(c, xs):
            lp, lc = xs
            return _hybrid_block(cfg, rules, pos, c, lp,
                                 cache=lc, cache_len=cache_len)
        x, new_cache = jax.lax.scan(f, x, (params["layers"], cache),
                                    unroll=_unroll(cfg))
    else:
        def f(c, xs):
            lp, lc = xs
            return _attn_block(cfg, rules, pos, enc_out, c, lp,
                               cache=lc, cache_len=cache_len)
        x, new_cache = jax.lax.scan(f, x, (params["layers"], cache),
                                    unroll=_unroll(cfg))

    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = x @ params["tok"]["lm_head"]
    return logits, new_cache
