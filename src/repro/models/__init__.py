from . import gcn, hetero_gcn, layers, sharding, ssm, transformer

__all__ = ["gcn", "hetero_gcn", "layers", "sharding", "ssm", "transformer"]
