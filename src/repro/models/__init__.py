from . import gcn, layers, sharding, ssm, transformer

__all__ = ["gcn", "layers", "sharding", "ssm", "transformer"]
