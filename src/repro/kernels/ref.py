"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tile_fused_gemm_spmm_wf0(cols0, vals0, b, c, *, t: int):
    """Oracle for kernels.tile_fused_gemm_spmm_wf0."""
    n_tiles, j0_max, w = cols0.shape
    d1 = (b @ c).astype(jnp.float32)
    d1_tiles = d1.reshape(n_tiles, t, -1)
    # tile-local cols index into that tile's D1 rows
    gathered = jax.vmap(lambda dt, cc: dt[cc])(d1_tiles, cols0)  # (T, j0, w, c)
    rows = jnp.einsum("tjw,tjwc->tjc", vals0.astype(jnp.float32), gathered)
    return d1.astype(b.dtype), rows.astype(b.dtype)


def tile_fused_spmm_spmm_wf0(op1_cols, op1_vals, d1_spill, cols0, vals0, c,
                             *, t: int):
    """Oracle for kernels.tile_fused_spmm_spmm_wf0."""
    n_tiles = op1_cols.shape[0]
    c_col = c.shape[1]
    # op-1: hybrid ELL body gather over global C, plus the spill delta
    gathered1 = c[op1_cols]                               # (T, t, w1, c)
    d1_tiles = jnp.einsum("vtw,vtwc->vtc", op1_vals.astype(jnp.float32),
                          gathered1.astype(jnp.float32))
    d1_tiles = d1_tiles + d1_spill.reshape(n_tiles, t, c_col)
    # fused op: tile-local cols index into the tile's own D1 rows
    gathered0 = jax.vmap(lambda dt, cc: dt[cc])(d1_tiles, cols0)
    rows = jnp.einsum("vjw,vjwc->vjc", vals0.astype(jnp.float32),
                      gathered0.astype(jnp.float32))
    return (d1_tiles.reshape(n_tiles * t, c_col).astype(c.dtype),
            rows.astype(c.dtype))


def spmm_ell(cols, vals, x):
    return jnp.einsum("iw,iwc->ic", vals.astype(jnp.float32),
                      x[cols].astype(jnp.float32)).astype(x.dtype)


def ffn(x, w1, w2, act: str = "gelu"):
    h = x.astype(jnp.float32) @ w1.astype(jnp.float32)
    h = jax.nn.gelu(h) if act == "gelu" else (
        jax.nn.silu(h) if act == "silu" else h)
    return (h @ w2.astype(jnp.float32)).astype(x.dtype)


def moe_ffn(x, w1, w2, act: str = "silu"):
    h = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), w1.astype(jnp.float32))
    h = jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(jnp.float32)).astype(x.dtype)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              sm_scale: float | None = None):
    b, h, sq, d = q.shape
    sk = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
