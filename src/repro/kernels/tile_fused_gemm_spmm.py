"""Pallas TPU kernel for wavefront-0 fused tiles of GeMM-SpMM.

TPU adaptation of the paper's fused code (Listing 1).  One grid step = one
fused tile (the paper's OpenMP-parallel tile loop becomes the Pallas grid;
grid steps are independent — exactly the wavefront-0 guarantee).

Per tile ``v`` covering rows ``[v*t, (v+1)*t)``:

  1. GeMM:  ``D1_t = B_t @ C``      — MXU matmul, ``B_t`` staged to VMEM by
     BlockSpec, ``D1_t`` *never leaves VMEM* before its consumers run.
  2. Fused SpMM: the tile-local rows of ``A`` are densified on the fly from
     ELL into a ``(j0_max, t)`` matrix ``W`` via one-hot accumulation, and the
     fused rows are ``W @ D1_t`` — a second MXU matmul.  This replaces the
     CPU scalar gather: on TPU, gather-by-matmul is the idiomatic way to keep
     the systolic array busy (DESIGN.md §2).

The tile size ``t`` is the TPU analogue of the paper's step-2 splitting: VMEM
working set is ``t*(bCol+cCol) + j0_max*(t+cCol)`` elements, uniform across
tiles, so step 2 reduces to choosing the largest 128-aligned ``t`` under the
VMEM budget (see ``ops.choose_kernel_tile``).

Wavefront 1 (the post-barrier tiles) runs as a second kernel (``spmm.py``)
reading the now-complete ``D1`` — the ``pallas_call`` boundary *is* the
paper's single synchronization barrier.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(cols_ref, vals_ref, b_ref, c_ref, d1_ref, rows_ref):
    # ---- GeMM part: D1 tile, stays in VMEM ----
    d1_t = jnp.dot(b_ref[...], c_ref[...],
                   preferred_element_type=jnp.float32)          # (t, cCol)
    d1_ref[...] = d1_t.astype(d1_ref.dtype)

    # ---- fused SpMM part: densify tile-local A rows, multiply on MXU ----
    cols = cols_ref[0]                                          # (j0_max, w)
    vals = vals_ref[0]                                          # (j0_max, w)
    t = d1_t.shape[0]
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)     # (1, t)

    def body(w, acc):
        onehot = (cols[:, w][:, None] == iota_t).astype(vals.dtype)  # (j0_max, t)
        return acc + vals[:, w][:, None] * onehot

    w_mat = jax.lax.fori_loop(
        0, cols.shape[1], body,
        jnp.zeros((cols.shape[0], t), vals.dtype))              # dense A tile
    rows = jnp.dot(w_mat, d1_t, preferred_element_type=jnp.float32)
    rows_ref[0] = rows.astype(rows_ref.dtype)


def tile_fused_gemm_spmm_wf0(cols0: jax.Array, vals0: jax.Array,
                             b: jax.Array, c: jax.Array,
                             *, t: int, interpret: bool | None = None):
    """Run wavefront 0.

    Args:
      cols0: (T0, j0_max, w) int32 tile-local ELL columns of fused A rows.
      vals0: (T0, j0_max, w) values.
      b: (T0*t, bCol) dense B (padded to a multiple of t).
      c: (bCol, cCol) dense C.
      t: uniform kernel tile size (rows of B / D1 per tile).
    Returns:
      d1: (T0*t, cCol) intermediate, rows0: (T0, j0_max, cCol) fused rows
      (caller scatters rows0 to D via the schedule's j_rows0).
    """
    return _tile_fused_gemm_spmm_wf0(cols0, vals0, b, c, t=t,
                                     interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("t", "interpret"))
def _tile_fused_gemm_spmm_wf0(cols0, vals0, b, c, *, t: int, interpret: bool):
    n_tiles, j0_max, w = cols0.shape
    b_col, c_col = c.shape
    assert b.shape[0] == n_tiles * t, (b.shape, n_tiles, t)
    out_shape = (
        jax.ShapeDtypeStruct((n_tiles * t, c_col), b.dtype),
        jax.ShapeDtypeStruct((n_tiles, j0_max, c_col), b.dtype),
    )
    grid = (n_tiles,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, j0_max, w), lambda v: (v, 0, 0)),
            pl.BlockSpec((1, j0_max, w), lambda v: (v, 0, 0)),
            pl.BlockSpec((t, b_col), lambda v: (v, 0)),
            pl.BlockSpec((b_col, c_col), lambda v: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t, c_col), lambda v: (v, 0)),
            pl.BlockSpec((1, j0_max, c_col), lambda v: (v, 0, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(cols0, vals0, b, c)
