"""Flash-attention Pallas kernel (online softmax, tiled to VMEM).

Attention is the transformer's instance of the paper's pattern: a two-matmul
chain ``O = P @ V`` with ``P = softmax(Q K^T)`` the (block-)sparse-after-
masking intermediate.  Tile fusion's insight — keep the intermediate tile in
fast memory and consume it immediately — is exactly the flash recurrence.
With a sliding window the score matrix is block-sparse and the fused tiles
over (q-block, kv-block) pairs mirror the paper's wavefront-0 tiles (all
dependencies inside the tile, no synchronization between q blocks).

Grid: (batch, heads, q_blocks, kv_blocks), kv innermost/sequential; running
max/denominator/accumulator live in VMEM scratch across kv steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .config import resolve_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_q: int, block_k: int, sm_scale: float,
            causal: bool, window: int, n_k_blocks: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                       # (bq, d)
    k = k_ref[0, 0]                                       # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0, 0], preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == n_k_blocks - 1)
    def _finish():
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, block_q: int = 128, block_k: int = 128,
                    causal: bool = True, window: int = 0,
                    sm_scale: float | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, H, Sk, D) -> (B, H, Sq, D).

    ``window > 0`` = sliding-window (block-sparse) attention; kv blocks fully
    outside the window are masked (a production TPU kernel would skip them —
    the FLOP saving is accounted in the roofline as block-sparsity).
    """
    # resolve outside the jit so PALLAS_INTERPRET changes apply per call
    return _flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                            causal=causal, window=window, sm_scale=sm_scale,
                            interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_k", "causal", "window", "sm_scale", "interpret"))
def _flash_attention(q, k, v, *, block_q, block_k, causal, window, sm_scale,
                     interpret):
    b, h, sq, d = q.shape
    sk = k.shape[2]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    nq, nk = sq // block_q, sk // block_k
    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, sm_scale=float(sm_scale),
        causal=causal, window=window, n_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, i, j: (b_, h_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
