"""Single place the Pallas kernels resolve their ``interpret`` default.

Compiled Pallas lowering exists for TPU (Mosaic); on the CPU backend the
kernels run in interpret mode (kernel body executed as XLA ops — same
numerics, same blocking).  Kernels take ``interpret=None`` and resolve it
here so a real backend never silently falls into interpret mode.

``PALLAS_INTERPRET=0/1`` force-overrides in either direction (used by the
kernel tests to pin a mode regardless of backend).
"""
from __future__ import annotations

import os

import jax


def default_interpret() -> bool:
    env = os.environ.get("PALLAS_INTERPRET")
    if env is not None:
        return env == "1"
    # only TPU has a compiled (Mosaic) lowering for these kernels; CPU *and*
    # GPU interpret (the kernels use pltpu scratch shapes — no Triton path)
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def compiled_or_forced() -> bool:
    """Capability gate for dispatching *to* the Pallas kernels: a compiled
    (Mosaic) lowering exists, or interpret mode was explicitly forced via
    ``PALLAS_INTERPRET=1`` (CI parity runs).  Interpret mode is never a
    perf win, so plain CPU/GPU — where ``default_interpret`` silently
    interprets — does not qualify; it must be opted into.  Owned here so
    the dispatch gate can never drift from how the kernels themselves
    resolve their mode."""
    if os.environ.get("PALLAS_INTERPRET") == "1":
        return True
    return jax.default_backend() == "tpu"
