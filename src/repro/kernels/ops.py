"""Public jit'd entry points for the Pallas kernels.

Dispatch policy: on TPU the kernels run compiled (interpret=False); on the
CPU backend they run in interpret mode (kernel body executed as XLA ops) —
same numerics, same blocking.  The choice is made once, in
``config.default_interpret`` (``PALLAS_INTERPRET`` can force either).
Each op also exposes an ``impl="xla"`` escape hatch used by the dry-run
(representative HLO without a TPU custom-call) and by sizes whose working set
exceeds the VMEM budget.
"""
from __future__ import annotations

from . import ref
from .config import default_interpret as _interpret
from .fused_ffn import fused_ffn as _fused_ffn_pallas
from .flash_attention import flash_attention as _flash_pallas
from .moe import fused_moe_ffn as _moe_pallas
from .spmm import spmm_ell as _spmm_pallas
from .tile_fused_gemm_spmm import tile_fused_gemm_spmm_wf0 as _tf_pallas
from .tile_fused_spmm_spmm import tile_fused_spmm_spmm_wf0 as _tfss_pallas

#: VMEM budget used by choose_kernel_tile (bytes); ~half of v5e VMEM.
VMEM_BUDGET = 64 * 1024 * 1024


def choose_kernel_tile(b_col: int, c_col: int, j0_max: int, w: int,
                       dtype_bytes: int = 4,
                       budget: int = VMEM_BUDGET) -> int:
    """TPU form of the paper's step-2 splitting: the largest 128-aligned
    uniform tile size t whose VMEM working set fits the budget.

    Working set (elements): B_t (t*bCol) + C (bCol*cCol) + D1_t (t*cCol)
      + ELL (2*j0_max*w) + densified A tile (j0_max*t) + rows (j0_max*cCol).
    """
    t = 128
    best = 128
    while t <= 8192:
        elems = (t * b_col + b_col * c_col + t * c_col
                 + 2 * j0_max * w + j0_max * t + j0_max * c_col)
        if elems * dtype_bytes > budget:
            break
        best = t
        t *= 2
    return best


def tile_fused_gemm_spmm_wf0(cols0, vals0, b, c, *, t: int,
                             impl: str = "pallas"):
    if impl == "xla":
        return ref.tile_fused_gemm_spmm_wf0(cols0, vals0, b, c, t=t)
    return _tf_pallas(cols0, vals0, b, c, t=t, interpret=_interpret())


def tile_fused_spmm_spmm_wf0(op1_cols, op1_vals, d1_spill, cols0, vals0, c,
                             *, t: int, impl: str = "pallas"):
    if impl == "xla":
        return ref.tile_fused_spmm_spmm_wf0(op1_cols, op1_vals, d1_spill,
                                            cols0, vals0, c, t=t)
    return _tfss_pallas(op1_cols, op1_vals, d1_spill, cols0, vals0, c, t=t,
                        interpret=_interpret())


def spmm_ell(cols, vals, x, *, block_rows: int = 256, impl: str = "pallas"):
    if impl == "xla":
        return ref.spmm_ell(cols, vals, x)
    return _spmm_pallas(cols, vals, x, block_rows=block_rows,
                        interpret=_interpret())


def fused_ffn(x, w1, w2, *, block_m: int = 256, block_f: int = 512,
              act: str = "gelu", impl: str = "pallas"):
    m, _ = x.shape
    f = w1.shape[1]
    if impl == "xla" or m % block_m or f % block_f:
        return ref.ffn(x, w1, w2, act=act)
    return _fused_ffn_pallas(x, w1, w2, block_m=block_m, block_f=block_f,
                             act=act, interpret=_interpret())


def fused_moe_ffn(x, w1, w2, *, block_c: int = 128, block_f: int = 512,
                  act: str = "silu", impl: str = "pallas"):
    _, cap, _ = x.shape
    f = w1.shape[2]
    if impl == "xla" or cap % block_c or f % block_f:
        return ref.moe_ffn(x, w1, w2, act=act)
    return _moe_pallas(x, w1, w2, block_c=block_c, block_f=block_f,
                       act=act, interpret=_interpret())


def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    causal: bool = True, window: int = 0,
                    sm_scale: float | None = None, impl: str = "pallas"):
    sq, sk = q.shape[2], k.shape[2]
    if impl == "xla" or sq % block_q or sk % block_k:
        return ref.attention(q, k, v, causal=causal, window=window,
                             sm_scale=sm_scale)
    return _flash_pallas(q, k, v, block_q=block_q, block_k=block_k,
                         causal=causal, window=window, sm_scale=sm_scale,
                         interpret=_interpret())
