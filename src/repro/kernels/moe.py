"""Fused MoE expert-FFN Pallas kernel.

The MoE layer is the LM-architecture instance of GeMM-SpMM tile fusion
(DESIGN.md §4): the dispatch matrix is the sparse ``A``; tokens routed to an
expert form a fused tile whose intermediate ``H = act(X_e W1_e)`` stays in
VMEM across the two expert matmuls.  Capacity-dispatched layout: tokens are
already gathered to (E, cap, d) — the gather/scatter (the wavefront-1
analogue) happens in XLA around the kernel.

Grid: (experts, cap_blocks, f_blocks), f innermost with output accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(x_ref, w1_ref, w2_ref, out_ref, *, act: str):
    f = pl.program_id(2)
    h = jnp.dot(x_ref[0], w1_ref[0], preferred_element_type=jnp.float32)
    if act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "silu":
        h = jax.nn.silu(h)
    part = jnp.dot(h.astype(x_ref.dtype), w2_ref[0],
                   preferred_element_type=jnp.float32)

    @pl.when(f == 0)
    def _init():
        out_ref[0] = part.astype(out_ref.dtype)

    @pl.when(f != 0)
    def _acc():
        out_ref[0] = (out_ref[0] + part).astype(out_ref.dtype)


def fused_moe_ffn(x: jax.Array, w1: jax.Array, w2: jax.Array,
                  *, block_c: int = 128, block_f: int = 512,
                  act: str = "silu", interpret: bool | None = None) -> jax.Array:
    """x: (E, cap, d); w1: (E, d, f); w2: (E, f, d) -> (E, cap, d)."""
    # resolve outside the jit so PALLAS_INTERPRET changes apply per call
    return _fused_moe_ffn(x, w1, w2, block_c=block_c, block_f=block_f,
                          act=act, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_f", "act", "interpret"))
def _fused_moe_ffn(x, w1, w2, *, block_c, block_f, act, interpret):
    e, cap, d = x.shape
    f = w1.shape[2]
    assert cap % block_c == 0 and f % block_f == 0, (cap, f, block_c, block_f)
    grid = (e, cap // block_c, f // block_f)
    return pl.pallas_call(
        functools.partial(_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e_, i, j: (e_, i, 0)),
            pl.BlockSpec((1, d, block_f), lambda e_, i, j: (e_, 0, j)),
            pl.BlockSpec((1, block_f, d), lambda e_, i, j: (e_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda e_, i, j: (e_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, cap, d), x.dtype),
        interpret=interpret,
    )(x, w1, w2)
