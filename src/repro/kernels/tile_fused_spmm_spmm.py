"""Pallas TPU kernel for wavefront-0 fused tiles of SpMM-SpMM.

TPU adaptation of the paper's fused sparse-sparse code (Listing 3): one grid
step = one fused tile, grid steps independent — the wavefront-0 guarantee.
This is the sparse-op-1 twin of ``tile_fused_gemm_spmm.py``; the GeMM stage
is replaced by a *sparse gather* of the tile's op-1 rows:

Per tile ``v`` covering D1 rows ``[v*t, (v+1)*t)``:

  1. op-1 SpMM: the tile's op-1 rows arrive as hybrid-ELL body
     ``(t, w1)`` with *global* columns into ``C``; they are densified on the
     fly into a ``(t, n)`` one-hot matrix and multiplied against ``C`` on
     the MXU — the TPU form of the row gather (no efficient VMEM
     row-gather exists; gather-by-matmul keeps the systolic array busy).
     Hub-row tails past the hybrid width cap are *pre-accumulated* by the
     caller into ``d1_spill`` (a ``(t, cCol)`` delta per tile, zeros when
     nothing spills) and added here, so ``D1_t`` is exact while the ELL
     body stays cap-bounded — one pathological row no longer dictates the
     kernel's static width.
  2. Fused SpMM: identical to the GeMM-SpMM kernel — tile-local fused A
     rows densify from ELL into ``(j0_max, t)`` and multiply ``D1_t``.

``D1_t`` never leaves VMEM between the two stages; the ``pallas_call``
boundary is the paper's single synchronization barrier, after which
wavefront 1 runs over the spilled ``D1`` (``spmm.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(op1_cols_ref, op1_vals_ref, spill_ref, cols_ref, vals_ref,
            c_ref, d1_ref, rows_ref, *, n_c_rows: int):
    # ---- op-1 SpMM part: densify the tile's op-1 ELL body, gather C ----
    o_cols = op1_cols_ref[0]                                    # (t, w1)
    o_vals = op1_vals_ref[0]                                    # (t, w1)
    c = c_ref[...]                                              # (n, cCol)
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (1, n_c_rows), 1)

    def op1_body(w, acc):
        onehot = (o_cols[:, w][:, None] == iota_n).astype(o_vals.dtype)
        return acc + o_vals[:, w][:, None] * onehot

    w1_mat = jax.lax.fori_loop(
        0, o_cols.shape[1], op1_body,
        jnp.zeros((o_cols.shape[0], n_c_rows), o_vals.dtype))   # (t, n)
    d1_t = jnp.dot(w1_mat, c, preferred_element_type=jnp.float32)
    d1_t = d1_t + spill_ref[...]             # hub-row tails past the cap
    d1_ref[...] = d1_t.astype(d1_ref.dtype)

    # ---- fused SpMM part: tile-local A rows, multiply on MXU ----
    cols = cols_ref[0]                                          # (j0_max, w0)
    vals = vals_ref[0]
    t = d1_t.shape[0]
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)

    def fused_body(w, acc):
        onehot = (cols[:, w][:, None] == iota_t).astype(vals.dtype)
        return acc + vals[:, w][:, None] * onehot

    w0_mat = jax.lax.fori_loop(
        0, cols.shape[1], fused_body,
        jnp.zeros((cols.shape[0], t), vals.dtype))              # (j0_max, t)
    rows = jnp.dot(w0_mat, d1_t, preferred_element_type=jnp.float32)
    rows_ref[0] = rows.astype(rows_ref.dtype)


def tile_fused_spmm_spmm_wf0(op1_cols: jax.Array, op1_vals: jax.Array,
                             d1_spill: jax.Array,
                             cols0: jax.Array, vals0: jax.Array,
                             c: jax.Array, *, t: int,
                             interpret: bool | None = None):
    """Run wavefront 0 of SpMM-SpMM.

    Args:
      op1_cols: (T0, t, w1) int32 hybrid-ELL body columns of the op-1 rows,
        *global* into C (pad col 0 / val 0).
      op1_vals: (T0, t, w1) values.
      d1_spill: (T0*t, cCol) pre-accumulated spill delta — contributions of
        op-1 entries past the hybrid width cap (zeros when none spill).
      cols0: (T0, j0_max, w0) int32 tile-local ELL columns of fused A rows.
      vals0: (T0, j0_max, w0) values.
      c: (n, cCol) dense C, staged to VMEM in full per grid step.
      t: uniform kernel tile size (rows of D1 per tile).
    Returns:
      d1: (T0*t, cCol) intermediate, rows0: (T0, j0_max, cCol) fused rows
      (caller scatters rows0 to D via the schedule's j_rows0).
    """
    return _tile_fused_spmm_spmm_wf0(op1_cols, op1_vals, d1_spill, cols0,
                                     vals0, c, t=t,
                                     interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("t", "interpret"))
def _tile_fused_spmm_spmm_wf0(op1_cols, op1_vals, d1_spill, cols0, vals0, c,
                              *, t: int, interpret: bool):
    n_tiles, t_in, w1 = op1_cols.shape
    assert t_in == t, (op1_cols.shape, t)
    _, j0_max, w0 = cols0.shape
    n, c_col = c.shape
    assert d1_spill.shape == (n_tiles * t, c_col), (d1_spill.shape, n_tiles, t)
    out_shape = (
        jax.ShapeDtypeStruct((n_tiles * t, c_col), c.dtype),
        jax.ShapeDtypeStruct((n_tiles, j0_max, c_col), c.dtype),
    )
    grid = (n_tiles,)
    return pl.pallas_call(
        functools.partial(_kernel, n_c_rows=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, t, w1), lambda v: (v, 0, 0)),
            pl.BlockSpec((1, t, w1), lambda v: (v, 0, 0)),
            pl.BlockSpec((t, c_col), lambda v: (v, 0)),
            pl.BlockSpec((1, j0_max, w0), lambda v: (v, 0, 0)),
            pl.BlockSpec((1, j0_max, w0), lambda v: (v, 0, 0)),
            pl.BlockSpec((n, c_col), lambda v: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t, c_col), lambda v: (v, 0)),
            pl.BlockSpec((1, j0_max, c_col), lambda v: (v, 0, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(op1_cols, op1_vals, d1_spill, cols0, vals0, c)
