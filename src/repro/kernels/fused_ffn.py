"""Fused transformer FFN kernel: out = act(X @ W1) @ W2, intermediate in VMEM.

The dense limiting case of tile fusion (DESIGN.md §4): when ``A`` is dense,
every second-op row fuses and the schedule degenerates to classic producer/
consumer fusion — the intermediate ``H = act(X W1)`` never round-trips HBM.

Grid: (m_blocks, f_blocks).  The f axis is the contraction of the second
matmul; the output block (indexed by m only) is revisited and accumulated
across f steps — this is the VMEM-budgeted split of the intermediate, i.e.
the paper's step-2 splitting applied to the dense case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(x_ref, w1_ref, w2_ref, out_ref, *, act: str):
    f = pl.program_id(1)
    h = jnp.dot(x_ref[...], w1_ref[...], preferred_element_type=jnp.float32)
    if act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "silu":
        h = jax.nn.silu(h)
    part = jnp.dot(h.astype(x_ref.dtype), w2_ref[...],
                   preferred_element_type=jnp.float32)

    @pl.when(f == 0)
    def _init():
        out_ref[...] = part.astype(out_ref.dtype)

    @pl.when(f != 0)
    def _acc():
        out_ref[...] = (out_ref[...] + part).astype(out_ref.dtype)


def fused_ffn(x: jax.Array, w1: jax.Array, w2: jax.Array,
              *, block_m: int = 256, block_f: int = 512,
              act: str = "gelu", interpret: bool | None = None) -> jax.Array:
    """x: (m, d), w1: (d, f), w2: (f, d) -> (m, d)."""
    # resolve outside the jit so PALLAS_INTERPRET changes apply per call,
    # not per trace
    return _fused_ffn(x, w1, w2, block_m=block_m, block_f=block_f, act=act,
                      interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_f", "act", "interpret"))
def _fused_ffn(x, w1, w2, *, block_m, block_f, act, interpret):
    m, d = x.shape
    f = w1.shape[1]
    assert m % block_m == 0 and f % block_f == 0, (m, f, block_m, block_f)
    grid = (m // block_m, f // block_f)
    return pl.pallas_call(
        functools.partial(_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((block_f, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, w1, w2)
