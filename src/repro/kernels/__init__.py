"""TPU Pallas kernels for the framework's compute hot-spots.

tile_fused_gemm_spmm — the paper's fused code (wavefront 0) on TPU
spmm                 — ELL SpMM (unfused baseline + wavefront 1)
fused_ffn            — dense limiting case of tile fusion
flash_attention      — the attention instance of the fused two-matmul chain
moe                  — expert-FFN tile fusion (sparse dispatch)
"""
from . import ops, ref

__all__ = ["ops", "ref"]
