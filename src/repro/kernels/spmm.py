"""Pallas ELL SpMM kernel: D[i] = sum_w vals[i, w] * X[cols[i, w]].

Used standalone (unfused baseline, wavefront-1 tiles) and as the second-op
code version inside the fused pipeline.  Rows are blocked over the grid; the
dense operand ``X`` is staged to VMEM in full (valid for the sizes this
framework feeds it: X = D1 tile or cCol-wide activations; ops.py falls back
to the XLA path above the VMEM limit).

The gather is expressed as a one-hot matmul over *column blocks* of X so the
MXU does the work (TPU has no efficient VMEM row-gather; DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .config import resolve_interpret


def _kernel(cols_ref, vals_ref, x_ref, out_ref, *, n_rows_x: int):
    cols = cols_ref[...]                                   # (bm, w)
    vals = vals_ref[...]                                   # (bm, w)
    x = x_ref[...]                                         # (n, c)
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (1, n_rows_x), 1)

    def body(w, acc):
        onehot = (cols[:, w][:, None] == iota_n).astype(vals.dtype)  # (bm, n)
        return acc + vals[:, w][:, None] * onehot

    w_mat = jax.lax.fori_loop(0, cols.shape[1], body,
                              jnp.zeros((cols.shape[0], n_rows_x), vals.dtype))
    out_ref[...] = jnp.dot(w_mat, x, preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _spmm_ell(cols: jax.Array, vals: jax.Array, x: jax.Array,
              *, block_rows: int, interpret: bool) -> jax.Array:
    n_rows, w = cols.shape
    n, c = x.shape
    # rows that don't fill the last block are padded with col=0/val=0 slots
    # (contribute nothing) and sliced off the output
    pad = -n_rows % block_rows
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
    grid = ((n_rows + pad) // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, n_rows_x=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((n, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows + pad, c), x.dtype),
        interpret=interpret,
    )(cols, vals, x)
    return out[:n_rows] if pad else out


def spmm_ell(cols: jax.Array, vals: jax.Array, x: jax.Array,
             *, block_rows: int = 256,
             interpret: bool | None = None) -> jax.Array:
    """ELL SpMM.  cols/vals: (n_rows, w); x: (n, c).  Any n_rows (padded to a
    block_rows multiple internally)."""
    return _spmm_ell(cols, vals, x, block_rows=block_rows,
                     interpret=resolve_interpret(interpret))
