"""Production meshes.

Functions (not module-level constants) so importing never touches jax
device state.  Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis composes
with ``data`` for batch sharding — gradient all-reduce is hierarchical
(reduce-scatter in-pod over ICI, all-reduce across pods over DCN).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---- hardware constants for the roofline (TPU v5e) ----
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link (~)
