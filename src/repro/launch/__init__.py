from . import mesh, partitioning, steps

__all__ = ["mesh", "partitioning", "steps"]
