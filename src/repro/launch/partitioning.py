"""Per-arch parallel plan: input specs, parameter/cache shardings, rules.

``input_specs`` returns ShapeDtypeStruct stand-ins (no allocation) for every
model input of a (arch × shape) cell — the dry-run contract.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config, get_shape
from ..models import transformer as T
from ..models.sharding import ShardingRules, param_shardings
from .mesh import batch_axes


def make_rules(cfg, mesh) -> ShardingRules:
    model_size = dict(zip(mesh.axis_names,
                          mesh.devices.shape)).get("model", 1)
    return ShardingRules(
        batch_axes=batch_axes(mesh),
        model_axis="model",
        shard_heads=(cfg.n_heads % model_size == 0),
        mesh=mesh,
    )


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct for every input of the cell's step function."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    b, s = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    if shape.kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)} \
            if cfg.frontend == "none" or cfg.encoder_layers else \
            {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), f32)}
    elif cfg.frontend == "none" or cfg.encoder_layers:
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:
        batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)}
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), f32)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return batch


def batch_shardings(batch, mesh):
    ba = batch_axes(mesh)
    def spec(leaf):
        b = leaf.shape[0]
        n = 1
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for ax in ba:
            n *= sizes[ax]
        if b % n == 0:
            return NamedSharding(mesh, P(ba, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())      # e.g. long_500k batch=1
    return jax.tree.map(spec, batch)


def abstract_params(cfg):
    """Parameter ShapeDtypeStructs without allocating (eval_shape)."""
    return jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_cache(cfg, batch_size: int, max_len: int):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch_size, max_len))


def opt_shardings(p_shardings, params, mesh):
    """ZeRO-1: optimizer moments additionally shard over the data axes.

    §Perf iteration 5: f32 mu/nu only model-sharded = 36GB/device for the
    72B arch (6x over v5e HBM).  For each leaf, add the data axes to the
    largest dim they divide that the param sharding leaves free.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ba = batch_axes(mesh)
    n_data = 1
    for ax in ba:
        n_data *= sizes[ax]

    def one(leaf, ps):
        spec = list(ps.spec) + [None] * (len(leaf.shape) - len(ps.spec))
        free = [i for i, s in enumerate(spec) if s is None
                and leaf.shape[i] % n_data == 0 and leaf.shape[i] > 1]
        if free:
            i = max(free, key=lambda j: leaf.shape[j])
            spec[i] = ba if len(ba) > 1 else ba[0]
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, params, p_shardings)


def cache_shardings(cfg, cache, mesh):
    """KV/state caches: batch over data axes; kv-heads over model when they
    divide; replicate otherwise (divisibility-guarded, like params)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ba = batch_axes(mesh)
    n_b = 1
    for ax in ba:
        n_b *= sizes[ax]
    m = sizes.get("model", 1)

    def spec(leaf):
        shp = leaf.shape
        # find the batch dim: first dim equal between layouts is layer count;
        # caches built by init_cache have layer leading, batch second
        dims = [None] * len(shp)
        if len(shp) >= 2 and shp[1] % n_b == 0 and shp[1] > 1:
            dims[1] = ba
        # kv-head axis (position 2 for (L,B,Hkv,C,dh)) over model
        if len(shp) == 5 and shp[2] % m == 0:
            dims[2] = "model"
        return NamedSharding(mesh, P(*dims))
    return jax.tree.map(spec, cache)


def plan(arch: str, shape_name: str, mesh, *, unroll: bool = False,
         cfg_replace: dict | None = None):
    """Everything the dry-run/trainer needs for one cell."""
    import dataclasses
    cfg = get_config(arch)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    if cfg_replace:
        cfg = dataclasses.replace(cfg, **cfg_replace)
    shape = get_shape(shape_name)
    rules = make_rules(cfg, mesh)
    batch = input_specs(arch, shape_name)
    b_shard = batch_shardings(batch, mesh)
    p_abs = abstract_params(cfg)
    p_shard = param_shardings(p_abs, mesh)
    out = dict(cfg=cfg, shape=shape, rules=rules, batch=batch,
               batch_shardings=b_shard, params=p_abs,
               param_shardings=p_shard)
    if shape.kind == "decode":
        cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        out["cache"] = cache
        out["cache_shardings"] = cache_shardings(cfg, cache, mesh)
    return out
