"""Fault-tolerant training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \\
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault-tolerance features (DESIGN.md §5):
  * step-tagged atomic checkpoints (params + opt state + data cursor),
    restore picks the newest complete step — preemption-safe;
  * deterministic resumable data stream: batch(step) is a pure function,
    so restart never skips or repeats data;
  * elastic restart: checkpoints are stored unsharded and re-sharded onto
    the restarted job's mesh (device count may change between runs);
  * straggler watchdog: per-step wall-time EMA; steps slower than
    ``--straggler-factor``× the EMA are logged (on a real fleet this signal
    feeds the coordinator's hot-spare swap);
  * ``--simulate-preemption N`` kills the loop at step N (exit 17); the
    wrapper/test restarts the command and training resumes exactly.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from .. import checkpoint as ckpt
from ..configs import get_config
from ..data import DataConfig, SyntheticStream
from ..models import transformer as T
from ..optim import OptConfig, adamw
from . import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--simulate-preemption", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)

    dkind = "lm" if (cfg.frontend == "none" or cfg.encoder_layers) else "embeds"
    data = SyntheticStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed, kind=dkind,
        d_model=cfg.d_model))

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw.init(params)
    start_step = 0

    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            (params, opt_state), extra = ckpt.restore(
                args.ckpt_dir, latest, (params, opt_state))
            start_step = extra["step"]
            print(f"[restore] resumed from step {start_step}", flush=True)

    train_step = steps.make_train_step(cfg, opt_cfg, rules=None, jit=True)

    ema = None
    for step in range(start_step, args.steps):
        t0 = time.time()
        raw = data.batch_at(step)
        batch = {k: jax.numpy.asarray(v) for k, v in raw.items()}
        if cfg.encoder_layers:
            batch["enc_embeds"] = jax.numpy.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jax.numpy.float32)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        if dt > args.straggler_factor * ema and step > start_step + 3:
            print(f"[straggler] step {step} took {dt:.2f}s "
                  f"(ema {ema:.2f}s)", flush=True)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
        if np.isnan(loss):
            print("[fatal] NaN loss", flush=True)
            sys.exit(2)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state),
                      extra={"step": step + 1, "arch": args.arch})
            ckpt.prune(args.ckpt_dir, keep=3)
        if args.simulate_preemption and step + 1 == args.simulate_preemption:
            print(f"[preempted] simulated preemption at step {step+1}",
                  flush=True)
            sys.exit(17)

    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, (params, opt_state),
                  extra={"step": args.steps, "arch": args.arch})
    print(f"done: {args.steps} steps, final loss {loss:.4f}", flush=True)


if __name__ == "__main__":
    main()
