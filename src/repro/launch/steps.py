"""Step functions: train_step / prefill_step / serve_step factories."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..optim import adamw


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_loss_fn(cfg, rules):
    def loss_fn(params, batch):
        logits = T.forward(cfg, params, batch, rules=rules)
        loss = cross_entropy(logits, batch["labels"])
        return loss, {"loss": loss}
    return loss_fn


def make_train_step(cfg, opt_cfg, rules):
    loss_fn = make_loss_fn(cfg, rules)

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {**aux, **om}
    return train_step


def make_prefill_step(cfg, rules):
    def prefill_step(params, batch):
        return T.forward(cfg, params, batch, rules=rules)
    return prefill_step


def make_serve_step(cfg, rules):
    """One decode step: new token in, next-token logits + updated cache out."""
    def serve_step(params, batch, cache, cache_len):
        logits, new_cache = T.decode_step(
            cfg, params, batch, cache, cache_len, rules=rules)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return serve_step
