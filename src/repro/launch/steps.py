"""Step functions: train_step / prefill_step / serve_step factories."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..optim import adamw


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_loss_fn(cfg, rules):
    def loss_fn(params, batch):
        logits = T.forward(cfg, params, batch, rules=rules)
        loss = cross_entropy(logits, batch["labels"])
        return loss, {"loss": loss}
    return loss_fn


def make_train_step(cfg, opt_cfg, rules, *, jit: bool = False):
    """Train-step factory.  ``jit=True`` returns the compiled step (the
    ``make_gcn_train_step`` convention) so drivers stop hand-wrapping;
    the default stays eager because the dry-run re-wraps with explicit
    in_shardings."""
    loss_fn = make_loss_fn(cfg, rules)

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {**aux, **om}
    return jax.jit(train_step) if jit else train_step


def make_gcn_train_step(model, *, lr: float = 0.3, fused: bool = True,
                        backend: str = None, mesh=None, jit: bool = True):
    """SGD train step for a ``models.gcn.GCN`` on the fused path.

    The returned ``step(params, x, y) -> (params, loss)`` differentiates
    through ``tile_fused_matmul``'s custom_vjp, so the backward runs the
    transposed fused products off the cached transpose schedules — on
    whatever backend the knobs (or Eq-3 auto selection) resolve to,
    including under a non-trivial ``mesh=``.  ``jit=False`` returns the
    eager step (useful for cache-behavior tests)."""
    def step(params, x, y):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, x, y, fused=fused, backend=backend,
                                 mesh=mesh))(params)
        return [w - lr * g for w, g in zip(params, grads)], loss
    return jax.jit(step) if jit else step


def make_prefill_step(cfg, rules, *, jit: bool = False):
    def prefill_step(params, batch):
        return T.forward(cfg, params, batch, rules=rules)
    return jax.jit(prefill_step) if jit else prefill_step


def make_serve_step(cfg, rules, *, jit: bool = False):
    """One decode step: new token in, next-token logits + updated cache out."""
    def serve_step(params, batch, cache, cache_len):
        logits, new_cache = T.decode_step(
            cfg, params, batch, cache, cache_len, rules=rules)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return jax.jit(serve_step) if jit else serve_step
