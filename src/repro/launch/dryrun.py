import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all --both-meshes [--skip-existing]

Per cell:
  1. the FULL-DEPTH scan-over-layers step is lowered with sharded
     ShapeDtypeStruct inputs and compiled — the large-scale runnability
     proof and the memory_analysis source (no arrays are ever allocated);
  2. (single-pod roofline cells) two SHALLOW fully-unrolled variants are
     compiled and the per-layer FLOPs / bytes / collective-bytes rates are
     extrapolated to full depth.  This sidesteps a known XLA artifact: HLO
     cost_analysis counts a while-loop body ONCE regardless of trip count,
     so the scanned step under-reports per-step cost by ~n_layers.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from ..configs import cells, get_config, get_shape
from ..optim import OptConfig, adamw
from ..roofline import collective_bytes, model_flops, roofline
from . import partitioning, steps
from .mesh import make_production_mesh


def _compile(arch: str, shape_name: str, mesh, *, unroll: bool,
             cfg_replace: dict | None = None, override_rules=None):
    """Lower + compile one variant; return raw analysis artifacts."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    import jax.numpy as jnp

    pl_ = partitioning.plan(arch, shape_name, mesh, unroll=unroll,
                            cfg_replace=cfg_replace)
    cfg, shape = pl_["cfg"], pl_["shape"]
    rules = override_rules if override_rules is not None else pl_["rules"]
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            step = steps.make_train_step(cfg, OptConfig(), rules)
            opt_abs = jax.eval_shape(adamw.init, pl_["params"])
            # ZeRO-1 (§Perf iter 5): moments shard over data axes too
            moment_shard = partitioning.opt_shardings(
                pl_["param_shardings"], pl_["params"], mesh)
            opt_shard = type(opt_abs)(
                step=NamedSharding(mesh, P()),
                mu=moment_shard, nu=moment_shard)
            lowered = jax.jit(step, in_shardings=(
                pl_["param_shardings"], opt_shard, pl_["batch_shardings"]),
            ).lower(pl_["params"], opt_abs, pl_["batch"])
        elif shape.kind == "prefill":
            step = steps.make_prefill_step(cfg, rules)
            lowered = jax.jit(step, in_shardings=(
                pl_["param_shardings"], pl_["batch_shardings"]),
            ).lower(pl_["params"], pl_["batch"])
        else:
            step = steps.make_serve_step(cfg, rules)
            lowered = jax.jit(step, in_shardings=(
                pl_["param_shardings"], pl_["batch_shardings"],
                pl_["cache_shardings"], NamedSharding(mesh, P())),
            ).lower(pl_["params"], pl_["batch"], pl_["cache"],
                    jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "cfg": cfg, "shape": shape,
        "cost": cost, "mem": mem, "coll": coll,
        "lower_s": t_lower, "compile_s": t_compile,
    }


def _peak_bytes(mem):
    """Per-device peak memory.  ``CompiledMemoryStats.peak_memory_in_bytes``
    only exists on newer jaxlib / TPU runtimes; the CPU/host backend exposes
    just the component sizes, so derive the peak from those instead of
    silently reporting None."""
    if mem is None:
        return None
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak:
        return int(peak)
    parts = ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes")
    total = sum(int(getattr(mem, k, 0) or 0) for k in parts)
    total -= int(getattr(mem, "alias_size_in_bytes", 0) or 0)
    return max(total, 0)


def _depth_points(cfg):
    """Two shallow depths for the affine-in-depth extrapolation."""
    if cfg.block_pattern == "mlstm7+slstm":
        return 8, 16
    return 2, 4


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             roofline_terms: bool = True, override_rules=None,
             extra_tag: str = "", cfg_replace: dict | None = None,
             verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_dev = mesh.devices.size

    # ---- 1) full-depth scan compile: runnability proof + memory ----
    full = _compile(arch, shape_name, mesh, unroll=False,
                    cfg_replace=cfg_replace, override_rules=override_rules)
    mem = full["mem"]
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod, "tag": extra_tag, "n_devices": n_dev,
        "lower_s": round(full["lower_s"], 1),
        "compile_s": round(full["compile_s"], 1),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": _peak_bytes(mem),
        },
    }

    # ---- 2) depth-extrapolated roofline terms (single-pod cells) ----
    if roofline_terms:
        k1, k2 = _depth_points(cfg)
        enc_scale = cfg.encoder_layers / max(cfg.n_layers, 1)
        reps = []
        for k in (k1, k2):
            rep = dict(cfg_replace or {})
            rep.update(n_layers=k,
                       encoder_layers=int(round(k * enc_scale)))
            reps.append(_compile(arch, shape_name, mesh, unroll=True,
                                 cfg_replace=rep,
                                 override_rules=override_rules))

        def affine(get):
            y1, y2 = (float(get(r) or 0.0) for r in reps)
            slope = (y2 - y1) / (k2 - k1)
            eff_cfg = cfg_replace or {}
            depth = eff_cfg.get("n_layers", cfg.n_layers)
            return y2 + slope * (depth - k2)

        flops = affine(lambda r: r["cost"].get("flops"))
        bytes_acc = affine(lambda r: r["cost"].get("bytes accessed"))
        coll_total = affine(lambda r: r["coll"]["total_bytes"])
        coll_kinds = {
            kind: affine(lambda r, k_=kind: r["coll"]["bytes"][k_])
            for kind in reps[0]["coll"]["bytes"]
        }
        rl = roofline({"flops": flops, "bytes accessed": bytes_acc},
                      {"total_bytes": coll_total},
                      model_flops_global=model_flops(cfg, shape),
                      n_devices=n_dev)
        result["cost_analysis"] = {"flops": flops,
                                   "bytes accessed": bytes_acc}
        result["collectives"] = {"bytes": coll_kinds,
                                 "total_bytes": coll_total}
        result["roofline"] = rl.to_dict()
        result["extrapolation"] = {"depths": [k1, k2]}
    if verbose:
        print(json.dumps(result, indent=1, default=str))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape_name in todo:
        for mp in meshes:
            tag = f"{arch}_{shape_name}_{'512' if mp else '256'}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {tag}", flush=True)
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                # roofline terms only for the single-pod table (§Roofline)
                res = run_cell(arch, shape_name, multi_pod=mp,
                               roofline_terms=not mp, verbose=False)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1, default=str)
                if "roofline" in res:
                    r = res["roofline"]
                    print(f"[ok] {tag}: bottleneck={r['bottleneck']} "
                          f"compute={r['compute_s']:.2e}s "
                          f"memory={r['memory_s']:.2e}s "
                          f"coll={r['collective_s']:.2e}s "
                          f"useful={r['useful_ratio']:.2f} "
                          f"(compile {res['compile_s']}s)", flush=True)
                else:
                    print(f"[ok] {tag}: compiled "
                          f"(compile {res['compile_s']}s, peak "
                          f"{res['memory_analysis']['peak_bytes']})",
                          flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL] {tag}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        sys.exit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
