"""Batched serving driver: prefill + decode loop with KV cache, plus the
dynamic-pattern subgraph front end over the tile-fusion serving tier.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --subgraphs 24 \\
      --subgraph-nodes 256 --feat-dim 32 --out-dim 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.sparse.formats import csr_content_digest
from ..core.sparse.random import (induced_subgraph, perturb_rows,
                                  powerlaw_graph)
from ..core.tilefusion.serving import ServingTier
from ..models import transformer as T
from . import steps


class SubgraphFrontEnd:
    """Request-batching front of a ``ServingTier`` for GNN-style loads.

    Each request is ``(a, feats, w)`` — a sampled subgraph, its node
    features ``(a.n_cols, feat_dim)``, and a per-request weight
    ``(feat_dim, out_dim)`` — computing ``a @ (feats @ w)``.  ``submit``
    queues; ``flush`` groups queued requests by served pattern and stacks
    up to ``max_batch`` of them into ONE tier dispatch: features go
    side-by-side in B's columns and the weights block-diagonally in C, so
    one schedule lookup and one executor launch serve the whole stack
    (unused column blocks stay zero — the compiled shape never changes).
    Results come back in submit order."""

    def __init__(self, feat_dim: int, out_dim: int, max_batch: int = 4,
                 **tier_kw):
        self.feat_dim = feat_dim
        self.out_dim = out_dim
        self.max_batch = max(int(max_batch), 1)
        self.tier = ServingTier(b_col=feat_dim * self.max_batch,
                                c_col=out_dim * self.max_batch, **tier_kw)
        self._queue: list = []
        self.batches = 0

    def submit(self, a, feats, w) -> int:
        """Queue a request; returns its index into ``flush()``'s result."""
        self._queue.append((a, np.asarray(feats), np.asarray(w)))
        return len(self._queue) - 1

    def flush(self) -> list:
        """Serve every queued request; list of ``(n_rows, out_dim)`` outputs
        in submit order."""
        queue, self._queue = self._queue, []
        results: list = [None] * len(queue)
        groups: dict = {}
        for i, (a, _, _) in enumerate(queue):
            groups.setdefault(csr_content_digest(a), []).append(i)
        fd, od = self.feat_dim, self.out_dim
        for idxs in groups.values():
            for lo in range(0, len(idxs), self.max_batch):
                chunk = idxs[lo: lo + self.max_batch]
                a = queue[chunk[0]][0]
                b = np.zeros((a.n_cols, fd * self.max_batch), np.float32)
                c = np.zeros((fd * self.max_batch, od * self.max_batch),
                             np.float32)
                for s, i in enumerate(chunk):
                    b[:, s * fd:(s + 1) * fd] = queue[i][1]
                    c[s * fd:(s + 1) * fd, s * od:(s + 1) * od] = queue[i][2]
                d = np.asarray(self.tier.matmul(a, b, c))
                # the stacked call resolved the schedule once; count the
                # piggy-backed requests so tier stats stay per-request
                for _ in chunk[1:]:
                    self.tier.schedule_for(a)
                for s, i in enumerate(chunk):
                    results[i] = d[:, s * od:(s + 1) * od]
                self.batches += 1
        return results


def _run_subgraph_stream(args):
    """Drive a sampled-subgraph request stream through the front end."""
    rng = np.random.default_rng(args.seed)
    base = powerlaw_graph(8 * args.subgraph_nodes, avg_deg=6, seed=args.seed)
    fe = SubgraphFrontEnd(args.feat_dim, args.out_dim, args.max_batch,
                          p=8, cache_size=600_000.0, ct_size=256)
    windows = [induced_subgraph(base, s, args.subgraph_nodes)
               for s in (0, args.subgraph_nodes, 3 * args.subgraph_nodes)]
    # sampler streams drift: mostly the current minibatch pattern, some
    # re-sampled neighbor sets, the odd jump to a fresh sample window
    current = windows[0]
    t0 = time.time()
    served = 0
    while served < args.subgraphs:
        n_batch = min(args.max_batch, args.subgraphs - served)
        for _ in range(n_batch):
            r = rng.random()
            if r < 0.1 and served:
                current = windows[int(rng.integers(len(windows)))]
            elif r < 0.4:
                k = max(1, current.n_rows // 50)
                current = perturb_rows(
                    current, rng.choice(current.n_rows, k, replace=False),
                    seed=int(rng.integers(1 << 31)))
            a = current
            feats = rng.standard_normal((a.n_cols, args.feat_dim))
            w = rng.standard_normal((args.feat_dim, args.out_dim))
            fe.submit(a, feats, w)
            served += 1
        outs = fe.flush()
        assert all(o is not None for o in outs)
    dt = time.time() - t0
    st = fe.tier.stats
    print(f"served {served} subgraph requests in {dt:.2f}s "
          f"({served / max(dt, 1e-9):.1f} req/s) over {fe.batches} batched "
          f"dispatches")
    print(f"tier: hit_rate={fe.tier.hit_rate():.2f} exact={st['exact_hits']} "
          f"incremental={st['incremental']} rebuilds={st['rebuilds']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--subgraphs", type=int, default=0,
                    help="serve N sampled-subgraph requests through the "
                         "tile-fusion serving tier instead of the LM loop")
    ap.add_argument("--subgraph-nodes", type=int, default=256)
    ap.add_argument("--feat-dim", type=int, default=32)
    ap.add_argument("--out-dim", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args(argv)

    if args.subgraphs:
        _run_subgraph_stream(args)
        return

    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    b = args.batch
    prompts = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)
    serve_step = steps.make_serve_step(cfg, rules=None, jit=True)

    # batched prefill: one compiled call fills every layer's KV/state cache
    cache = T.init_cache(cfg, b, max_len)
    t0 = time.time()
    batch = {"tokens": prompts}
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.zeros(
            (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    next_tok, cache = serve_step(params, batch, cache, jnp.int32(0))
    prefill_t = time.time() - t0

    out = [next_tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        batch = {"tokens": next_tok[:, None]}
        if cfg.encoder_layers:
            batch["enc_embeds"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        next_tok, cache = serve_step(
            params, batch, cache, jnp.int32(args.prompt_len + i))
        out.append(next_tok)
    gen_t = time.time() - t0
    tokens = jnp.stack(out, axis=1)
    print(f"generated {tokens.shape} in {gen_t:.2f}s "
          f"({b * (args.gen - 1) / max(gen_t, 1e-9):.1f} tok/s), "
          f"prefill {prefill_t:.2f}s")
    print("sample:", tokens[0, :16].tolist())


if __name__ == "__main__":
    main()
