"""Batched serving driver: prefill + decode loop with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import transformer as T
from . import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    b = args.batch
    prompts = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)
    serve_step = jax.jit(steps.make_serve_step(cfg, rules=None))

    # batched prefill: one compiled call fills every layer's KV/state cache
    cache = T.init_cache(cfg, b, max_len)
    t0 = time.time()
    batch = {"tokens": prompts}
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.zeros(
            (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    next_tok, cache = serve_step(params, batch, cache, jnp.int32(0))
    prefill_t = time.time() - t0

    out = [next_tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        batch = {"tokens": next_tok[:, None]}
        if cfg.encoder_layers:
            batch["enc_embeds"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.float32)
        next_tok, cache = serve_step(
            params, batch, cache, jnp.int32(args.prompt_len + i))
        out.append(next_tok)
    gen_t = time.time() - t0
    tokens = jnp.stack(out, axis=1)
    print(f"generated {tokens.shape} in {gen_t:.2f}s "
          f"({b * (args.gen - 1) / max(gen_t, 1e-9):.1f} tok/s), "
          f"prefill {prefill_t:.2f}s")
    print("sample:", tokens[0, :16].tolist())


if __name__ == "__main__":
    main()
