"""Step-tagged, preemption-safe checkpointing.

Layout: ``<dir>/step_<n>/`` with one ``.npy`` per leaf + ``manifest.json``
(treedef, shapes, step, data-stream cursor).  Writes go to a temp dir and
are atomically renamed, so a preemption mid-write never corrupts the latest
checkpoint; restore picks the newest *complete* step.

Elastic restarts: leaves are stored unsharded (gathered); on restore the
trainer re-shards onto whatever mesh the restarted job has (DESIGN.md §5) —
node-count changes between runs are fine as long as the new mesh divides
the global batch.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes.append(str(arr.dtype))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.view(np.uint16)  # ml_dtypes (bf16) -> raw bits
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "extra": extra or {},
        "complete": True,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            mf = os.path.join(ckpt_dir, d, "manifest.json")
            if os.path.exists(mf):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like):
    """Restore into the structure of ``tree_like`` (shape/dtype template)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves), \
        "checkpoint/model structure mismatch"
    import jax.numpy as jnp
    new_leaves = []
    for i, old in enumerate(leaves):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        want = jnp.dtype(manifest["dtypes"][i])
        if arr.dtype != want:   # bf16 stored as uint16 bits
            arr = arr.view(want)
        assert tuple(old.shape) == tuple(arr.shape), \
            f"leaf shape mismatch: {old.shape} vs {arr.shape}"
        new_leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` checkpoints (bounded disk use)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
