"""Deterministic, shardable, resumable synthetic data pipeline.

Fault-tolerance contract (DESIGN.md §5): batch content is a pure function of
(seed, step, shard) — after a restart, resuming from checkpointed ``step``
reproduces the exact stream with no skipped or repeated batches, regardless
of how many hosts the job restarts with (elastic re-sharding safe).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lm"          # lm | embeds (stub frontends)
    d_model: int = 0          # for kind="embeds"


class SyntheticStream:
    """Zipf-distributed token LM stream (or gaussian embedding stream)."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 shard_count: int = 1):
        assert cfg.global_batch % shard_count == 0
        self.cfg = cfg
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.local_batch = cfg.global_batch // shard_count

    def batch_at(self, step: int) -> dict:
        """Pure function of step — the resumability guarantee."""
        cfg = self.cfg
        # fold shard and step into the key so any shard layout is reproducible
        rows = []
        base = np.random.default_rng(
            (cfg.seed, step)).integers(0, 2**31 - 1)
        for r in range(self.local_batch):
            gid = self.shard_index * self.local_batch + r
            rng = np.random.default_rng((base, gid))
            if cfg.kind == "lm":
                # Zipf-ish: heavy head like natural text
                u = rng.random(cfg.seq_len + 1)
                tok = np.minimum(
                    (cfg.vocab_size * u ** 3).astype(np.int64),
                    cfg.vocab_size - 1)
                rows.append(tok)
            else:
                rows.append(rng.standard_normal(
                    (cfg.seq_len + 1, cfg.d_model)).astype(np.float32))
        arr = np.stack(rows)
        if cfg.kind == "lm":
            return {"tokens": arr[:, :-1].astype(np.int32),
                    "labels": arr[:, 1:].astype(np.int32)}
        return {"embeds": arr[:, :-1],
                "labels": np.zeros((self.local_batch, cfg.seq_len), np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
