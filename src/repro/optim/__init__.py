from .adamw import OptConfig, OptState, init, update, schedule, global_norm

__all__ = ["OptConfig", "OptState", "init", "update", "schedule",
           "global_norm"]
