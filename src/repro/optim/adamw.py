"""AdamW + global-norm clipping + warmup-cosine schedule (pure pytrees)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


def init(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=z,
                    nu=jax.tree.map(jnp.copy, z))


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
