"""Synthetic sparsity generators.

SuiteSparse is not available offline; these generators produce the two matrix
families the paper evaluates (§4.1.2): (I) SPD/stencil-like scientific matrices
(banded, high fused ratio) and (II) graph matrices (power-law degree, lower
fused ratio).  Deterministic given a seed.
"""
from __future__ import annotations

import numpy as np

from .formats import CSR


def banded_spd(n: int, bandwidth: int = 8, seed: int = 0) -> CSR:
    """Banded symmetric positive-definite-like matrix (paper's group I)."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for off in range(1, bandwidth + 1):
        keep = rng.random(n - off) < 0.8
        idx = np.nonzero(keep)[0]
        v = rng.standard_normal(idx.shape[0]) * 0.1
        rows.append(idx); cols.append(idx + off); vals.append(v)
        rows.append(idx + off); cols.append(idx); vals.append(v)
    # strong diagonal for SPD-ness
    rows.append(np.arange(n)); cols.append(np.arange(n))
    vals.append(np.full(n, bandwidth + 1.0))
    return CSR.from_coo(
        n, n,
        np.concatenate(rows).astype(np.int64),
        np.concatenate(cols).astype(np.int64),
        np.concatenate(vals),
    )


def powerlaw_graph(n: int, avg_deg: int = 8, alpha: float = 2.1, seed: int = 0) -> CSR:
    """Power-law (scale-free-ish) adjacency matrix (paper's group II, graphs)."""
    rng = np.random.default_rng(seed)
    # degree-proportional endpoint sampling (Chung-Lu style)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-1.0 / (alpha - 1.0))
    p = w / w.sum()
    m = n * avg_deg // 2
    src = rng.choice(n, size=m, p=p)
    dst = rng.choice(n, size=m, p=p)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    rows = np.concatenate([src, dst]).astype(np.int64)
    cols = np.concatenate([dst, src]).astype(np.int64)
    vals = np.ones(rows.shape[0], dtype=np.float64)
    # add self loops (GCN-normalized adjacency has them)
    rows = np.concatenate([rows, np.arange(n, dtype=np.int64)])
    cols = np.concatenate([cols, np.arange(n, dtype=np.int64)])
    vals = np.concatenate([vals, np.ones(n)])
    a = CSR.from_coo(n, n, rows, cols, vals)
    return a


def hub_powerlaw(n: int, avg_deg: int = 8, seed: int = 0) -> CSR:
    """Power-law graph with one row boosted to degree ~n/2 — the single
    max-degree hub that makes pad-to-max ELL width explode (the hybrid
    width-cap stress case shared by benchmarks and regression tests)."""
    base = powerlaw_graph(n, avg_deg, seed=seed)
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(base.indptr))
    hub = int(np.random.default_rng(seed).integers(n))
    hcols = np.arange(0, n, 2, dtype=np.int64)
    return CSR.from_coo(
        n, n,
        np.concatenate([rows, np.full(hcols.shape[0], hub, np.int64)]),
        np.concatenate([base.indices.astype(np.int64), hcols]),
        np.concatenate([base.data, np.ones(hcols.shape[0])]))


def block_diag_noise(n: int, block: int = 256, density: float = 0.3,
                     off_frac: float = 0.05, seed: int = 0) -> CSR:
    """Mostly block-diagonal matrix with a sprinkle of off-block entries.

    High fused-ratio family — models locality-friendly reordered matrices.
    """
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for b0 in range(0, n, block):
        b1 = min(b0 + block, n)
        sz = b1 - b0
        k = int(density * sz * 4)
        rows.append(rng.integers(b0, b1, k))
        cols.append(rng.integers(b0, b1, k))
    k_off = int(off_frac * n * 4)
    rows.append(rng.integers(0, n, k_off))
    cols.append(rng.integers(0, n, k_off))
    rows = np.concatenate(rows).astype(np.int64)
    cols = np.concatenate(cols).astype(np.int64)
    vals = rng.standard_normal(rows.shape[0])
    rows = np.concatenate([rows, np.arange(n, dtype=np.int64)])
    cols = np.concatenate([cols, np.arange(n, dtype=np.int64)])
    vals = np.concatenate([vals, np.ones(n)])
    return CSR.from_coo(n, n, rows, cols, vals)


def induced_subgraph(base: CSR, start: int, n_sub: int) -> CSR:
    """Contiguous induced subgraph: rows/columns ``[start, start+n_sub)``
    of ``base``, relabeled to ``[0, n_sub)``.

    The neighbor-sampled minibatch stand-in for serving streams: a
    sampler relabels the sampled node set contiguously, so the served
    adjacency is exactly an induced submatrix of the (reordered) graph.
    Deterministic — perturbation comes from ``perturb_rows``."""
    stop = min(start + n_sub, base.n_rows)
    lo, hi = int(base.indptr[start]), int(base.indptr[stop])
    cols = base.indices[lo:hi].astype(np.int64)
    vals = base.data[lo:hi]
    rows = np.repeat(np.arange(start, stop, dtype=np.int64),
                     np.diff(base.indptr[start:stop + 1]))
    keep = (cols >= start) & (cols < stop)
    return CSR.from_coo(stop - start, stop - start, rows[keep] - start,
                        cols[keep] - start, vals[keep])


def perturb_rows(a: CSR, rows: np.ndarray, seed: int = 0) -> CSR:
    """Re-sample the neighbor sets of ``rows`` (degree preserved, fresh
    uniform targets and values) — the "same subgraph, a few re-sampled
    nodes" delta between consecutive requests of a serving stream."""
    rng = np.random.default_rng(seed)
    rows = np.unique(np.asarray(rows, dtype=np.int64))
    counts = np.diff(a.indptr).astype(np.int64)
    all_rows = np.repeat(np.arange(a.n_rows, dtype=np.int64), counts)
    dirty = np.zeros(a.n_rows, dtype=bool)
    dirty[rows] = True
    keep = ~dirty[all_rows]
    new_r = np.repeat(rows, counts[rows])
    new_c = rng.integers(0, a.n_cols, new_r.shape[0]).astype(np.int64)
    new_v = rng.uniform(0.5, 1.5, new_r.shape[0])
    return CSR.from_coo(
        a.n_rows, a.n_cols,
        np.concatenate([all_rows[keep], new_r]),
        np.concatenate([a.indices[keep].astype(np.int64), new_c]),
        np.concatenate([a.data[keep].astype(np.float64), new_v]))


SUITES = {
    "banded_spd": banded_spd,
    "powerlaw_graph": powerlaw_graph,
    "hub_powerlaw": hub_powerlaw,
    "block_diag_noise": block_diag_noise,
}


def benchmark_suite(n: int = 4096, seed: int = 0):
    """The benchmark matrix set: name -> CSR, spanning both paper groups."""
    return {
        "banded_spd_b4": banded_spd(n, bandwidth=4, seed=seed),
        "banded_spd_b16": banded_spd(n, bandwidth=16, seed=seed + 1),
        "powerlaw_d4": powerlaw_graph(n, avg_deg=4, seed=seed + 2),
        "powerlaw_d16": powerlaw_graph(n, avg_deg=16, seed=seed + 3),
        "blockdiag": block_diag_noise(n, block=512, seed=seed + 4),
    }
