from .formats import CSR, TileELL, block_csr_pattern
from . import random

__all__ = ["CSR", "TileELL", "block_csr_pattern", "random"]
