from .formats import (CSR, HybridELL, TileELL, block_csr_pattern,
                      hybrid_width_cap)
from . import random

__all__ = ["CSR", "HybridELL", "TileELL", "block_csr_pattern",
           "hybrid_width_cap", "random"]
