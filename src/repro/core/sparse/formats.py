"""Sparse matrix containers used across the framework.

CSR is the scheduler-side format (numpy, host).  The kernel-side formats are
static-shape paddded layouts (tile-local ELL / BCSR) that XLA and Pallas can
consume; conversion happens once per sparsity pattern, amortized exactly like
the paper's scheduler (§4.2.3).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSR:
    """Host-side CSR matrix (numpy)."""

    n_rows: int
    n_cols: int
    indptr: np.ndarray   # int32 (n_rows+1,)
    indices: np.ndarray  # int32 (nnz,)
    data: np.ndarray     # float (nnz,)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=self.data.dtype)
        for i in range(self.n_rows):
            cols, vals = self.row(i)
            out[i, cols] += vals
        return out

    @staticmethod
    def from_dense(a: np.ndarray) -> "CSR":
        n_rows, n_cols = a.shape
        indptr = [0]
        indices = []
        data = []
        for i in range(n_rows):
            (cols,) = np.nonzero(a[i])
            indices.append(cols.astype(np.int32))
            data.append(a[i, cols])
            indptr.append(indptr[-1] + cols.shape[0])
        return CSR(
            n_rows=n_rows,
            n_cols=n_cols,
            indptr=np.asarray(indptr, dtype=np.int32),
            indices=np.concatenate(indices) if indices else np.zeros(0, np.int32),
            data=np.concatenate(data) if data else np.zeros(0, np.float64),
        )

    @staticmethod
    def from_coo(n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray,
                 vals: np.ndarray) -> "CSR":
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        # merge duplicates
        key = rows.astype(np.int64) * n_cols + cols
        uniq, inv = np.unique(key, return_inverse=True)
        merged = np.zeros(uniq.shape[0], dtype=vals.dtype)
        np.add.at(merged, inv, vals)
        urows = (uniq // n_cols).astype(np.int32)
        ucols = (uniq % n_cols).astype(np.int32)
        indptr = np.zeros(n_rows + 1, dtype=np.int32)
        np.add.at(indptr, urows + 1, 1)
        indptr = np.cumsum(indptr).astype(np.int32)
        return CSR(n_rows, n_cols, indptr, ucols, merged)


def block_csr_pattern(a: CSR, block: int) -> CSR:
    """Collapse a CSR matrix to its block-level sparsity pattern.

    Returns a CSR over (ceil(n/block) x ceil(m/block)) block grid where
    data[k] = number of scalar nonzeros inside block k.  This is the DAG the
    TPU-side scheduler runs on (DESIGN.md §2: block granularity).
    """
    nb_rows = -(-a.n_rows // block)
    nb_cols = -(-a.n_cols // block)
    rows = np.repeat(np.arange(a.n_rows, dtype=np.int64), np.diff(a.indptr))
    brows = (rows // block).astype(np.int64)
    bcols = (a.indices.astype(np.int64) // block)
    key = brows * nb_cols + bcols
    uniq, counts = np.unique(key, return_counts=True)
    urows = (uniq // nb_cols).astype(np.int32)
    ucols = (uniq % nb_cols).astype(np.int32)
    indptr = np.zeros(nb_rows + 1, dtype=np.int32)
    np.add.at(indptr, urows + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return CSR(nb_rows, nb_cols, indptr, ucols, counts.astype(np.float64))


@dataclasses.dataclass(frozen=True)
class TileELL:
    """Padded ELL layout for a set of CSR rows, static-shape for XLA.

    Each of n_rows has up to `width` (col, val) slots; padding uses col=0,
    val=0 so padded slots contribute nothing.
    """

    cols: np.ndarray  # int32 (n_rows, width)
    vals: np.ndarray  # float (n_rows, width)

    @staticmethod
    def from_csr_rows(a: CSR, rows: np.ndarray, width: int | None = None) -> "TileELL":
        counts = (a.indptr[rows + 1] - a.indptr[rows]).astype(np.int64)
        w = int(counts.max()) if width is None and rows.size else (width or 1)
        w = max(w, 1)
        cols = np.zeros((rows.shape[0], w), dtype=np.int32)
        vals = np.zeros((rows.shape[0], w), dtype=np.float64)
        for k, r in enumerate(rows):
            c, v = a.row(int(r))
            c, v = c[:w], v[:w]
            cols[k, : c.shape[0]] = c
            vals[k, : v.shape[0]] = v
        return TileELL(cols=cols, vals=vals)
