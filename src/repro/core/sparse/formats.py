"""Sparse matrix containers used across the framework.

CSR is the scheduler-side format (numpy, host).  The kernel-side formats are
static-shape paddded layouts (tile-local ELL / BCSR) that XLA and Pallas can
consume; conversion happens once per sparsity pattern, amortized exactly like
the paper's scheduler (§4.2.3).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSR:
    """Host-side CSR matrix (numpy)."""

    n_rows: int
    n_cols: int
    indptr: np.ndarray   # int32 (n_rows+1,)
    indices: np.ndarray  # int32 (nnz,)
    data: np.ndarray     # float (nnz,)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row_extents(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-row (min, max) column index, O(nnz) via ``ufunc.reduceat``.

        Empty rows get ``(n_cols, -1)`` so the Algorithm-1 containment test
        ``row_min >= i_start and row_max < i_end`` is vacuously true for
        them.  Memoized per instance (CSR is treated as immutable): the
        scheduler's step 1, step 2, and the autotune sweep all share one
        pass over the indices.
        """
        ext = getattr(self, "_row_extents", None)
        if ext is None:
            counts = np.diff(self.indptr)
            row_min = np.full(self.n_rows, self.n_cols, dtype=np.int64)
            row_max = np.full(self.n_rows, -1, dtype=np.int64)
            nonempty = counts > 0
            if nonempty.any():
                starts = self.indptr[:-1][nonempty]
                row_min[nonempty] = np.minimum.reduceat(self.indices, starts)
                row_max[nonempty] = np.maximum.reduceat(self.indices, starts)
            ext = (row_min, row_max)
            object.__setattr__(self, "_row_extents", ext)
        return ext

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=self.data.dtype)
        for i in range(self.n_rows):
            cols, vals = self.row(i)
            out[i, cols] += vals
        return out

    @staticmethod
    def from_dense(a: np.ndarray) -> "CSR":
        n_rows, n_cols = a.shape
        indptr = [0]
        indices = []
        data = []
        for i in range(n_rows):
            (cols,) = np.nonzero(a[i])
            indices.append(cols.astype(np.int32))
            data.append(a[i, cols])
            indptr.append(indptr[-1] + cols.shape[0])
        return CSR(
            n_rows=n_rows,
            n_cols=n_cols,
            indptr=np.asarray(indptr, dtype=np.int32),
            indices=np.concatenate(indices) if indices else np.zeros(0, np.int32),
            # preserve the source dtype even when every row is empty — a
            # hardcoded float64 here flows into operand_dtype_bytes and
            # misprices Eq-3 for f32/bf16 zero-nnz patterns
            data=np.concatenate(data) if data else np.zeros(0, a.dtype),
        )

    def transpose(self) -> "CSR":
        """``Aᵀ`` via the COO round-trip, memoized per instance (CSR is
        treated as immutable) with the back-pointer set so ``Aᵀᵀ is A``.

        This is what the differentiable fused path runs its backward
        against (the ``mm(sparse.t(), grad)`` structure of sparse autograd
        rules): the transpose is materialized once per matrix and every
        transpose-schedule inspection and ELL pack hangs off this one
        cached instance."""
        t = getattr(self, "_transpose", None)
        if t is None:
            rows = np.repeat(np.arange(self.n_rows, dtype=np.int32),
                             np.diff(self.indptr))
            t = CSR.from_coo(self.n_cols, self.n_rows,
                             self.indices.astype(np.int32), rows, self.data)
            object.__setattr__(self, "_transpose", t)
            object.__setattr__(t, "_transpose", self)
        return t

    @staticmethod
    def from_coo(n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray,
                 vals: np.ndarray, *, dtype=None) -> "CSR":
        # coerce up front so list inputs and zero-nnz patterns keep a real,
        # caller-controlled value dtype (pass dtype= for an empty build)
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        vals = np.asarray(vals, dtype=dtype)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        # merge duplicates
        key = rows.astype(np.int64) * n_cols + cols
        uniq, inv = np.unique(key, return_inverse=True)
        merged = np.zeros(uniq.shape[0], dtype=vals.dtype)
        np.add.at(merged, inv, vals)
        urows = (uniq // n_cols).astype(np.int32)
        ucols = (uniq % n_cols).astype(np.int32)
        indptr = np.zeros(n_rows + 1, dtype=np.int32)
        np.add.at(indptr, urows + 1, 1)
        indptr = np.cumsum(indptr).astype(np.int32)
        return CSR(n_rows, n_cols, indptr, ucols, merged)


def csr_content_digest(a: CSR) -> bytes:
    """Content hash of a CSR matrix (shape + pattern + values), memoized
    per instance (CSR is treated as immutable).  Keys every content-
    addressed cache in the system: the schedule/ELL caches and the per-
    schedule op-1 pack memo."""
    digest = getattr(a, "_content_digest", None)
    if digest is None:
        h = hashlib.blake2b(digest_size=16)
        h.update(np.asarray([a.n_rows, a.n_cols], np.int64).tobytes())
        h.update(np.ascontiguousarray(a.indptr, np.int32).tobytes())
        h.update(np.ascontiguousarray(a.indices, np.int32).tobytes())
        # tag the source dtype: the value bytes below are canonicalized to
        # f64, so without this, identical patterns held at f32 vs bf16
        # would collide — and dtype_bytes-priced entries would alias
        h.update(str(a.data.dtype).encode())
        h.update(np.ascontiguousarray(a.data, np.float64).tobytes())
        digest = h.digest()
        object.__setattr__(a, "_content_digest", digest)
    return digest


def csr_gather_rows(a: CSR, rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized multi-row gather: flat positions of ``rows``' entries.

    Returns ``(flat, lens)`` where ``a.indices[flat]`` / ``a.data[flat]``
    are the selected rows' entries concatenated in row order and ``lens[k]``
    is row ``rows[k]``'s nonzero count.  This is the O(nnz) backbone shared
    by every ELL packer and the Eq-3 cost model — no Python per-row loop.
    """
    rows = np.asarray(rows, dtype=np.int64)
    starts = a.indptr[rows].astype(np.int64)
    ends = a.indptr[rows + 1].astype(np.int64)
    lens = ends - starts
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, np.int64), lens
    # entry p of the concatenation lands at starts[k] + (p - cum[k-1])
    # = p + (ends[k] - cum[k]) for its row k — one arange + one repeat.
    cum = np.cumsum(lens)
    flat = np.arange(total, dtype=np.int64) + np.repeat(ends - cum, lens)
    return flat, lens


def ell_slot_coords(lens: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(row, slot) coordinates for ragged rows of sizes ``lens`` flattened.

    ``row[p]`` is the ragged-row id of flat entry ``p`` and ``slot[p]`` its
    position within that row — exactly the scatter targets of an ELL pack.
    """
    lens = np.asarray(lens, dtype=np.int64)
    total = int(lens.sum())
    row = np.repeat(np.arange(lens.shape[0], dtype=np.int64), lens)
    cum = np.cumsum(lens)
    slot = np.arange(total, dtype=np.int64) - np.repeat(cum - lens, lens)
    return row, slot


#: Degree quantile used when a HybridELL cap is requested by quantile rather
#: than by the traffic-optimal search — the autotune width-cap sweep tries
#: this alongside the optimal cap and pad-to-max.
DEFAULT_WIDTH_QUANTILE = 0.99


def hybrid_width_cap(counts: np.ndarray, quantile: float | None = None) -> int:
    """Width cap for a hybrid ELL body over rows of nonzero counts ``counts``.

    ``quantile=None`` (default) returns the *traffic-optimal* cap: the width
    ``w`` minimizing ``2 * n_rows * w + 3 * spill(w)`` where ``spill(w)`` is
    the number of entries past slot ``w`` — a body slot streams (col, val),
    a spilled entry (row, col, val), the same 2-vs-3 weighting the Eq-3
    packed-traffic pricing uses.  A quantile in (0, 1] caps at that degree
    quantile instead (1.0 degenerates to pad-to-max).  Always >= 1.
    """
    counts = np.asarray(counts, dtype=np.int64)
    if counts.size == 0:
        return 1
    if quantile is not None:
        return max(int(np.quantile(counts, quantile)), 1)
    n = counts.shape[0]
    cands = np.unique(np.concatenate([[1], np.unique(counts)]))
    cands = cands[cands >= 1]
    # spill(w) = sum(max(counts - w, 0)) for every candidate, vectorized via
    # a sort + suffix sums: rows with count > w each contribute (count - w)
    srt = np.sort(counts)
    suffix = np.concatenate([np.cumsum(srt[::-1])[::-1], [0]])
    pos = np.searchsorted(srt, cands, side="right")
    spill = suffix[pos] - (n - pos) * cands
    cost = 2 * n * cands + 3 * spill
    return int(cands[np.argmin(cost)])


@dataclasses.dataclass(frozen=True)
class HybridELL:
    """Width-capped ELL body + COO spill lanes — the hub-safe row format.

    Pad-to-max ELL packs every row to the *maximum* degree, so one hub row
    of a power-law graph inflates the whole allocation (``n_rows × max_deg``,
    GB-scale at GNN sizes).  HybridELL bounds the body width at a cap (a
    degree quantile or the traffic-optimal split, see ``hybrid_width_cap``):

      * **body** — ``cols``/``vals`` of shape ``(n_rows, width)``: each row's
        first ``width`` entries, padded with col=0/val=0 (padded slots
        contribute nothing to an SpMM).
      * **spill lanes** — the tail entries of rows wider than the cap, as
        flat COO triples ``(spill_rows, spill_cols, spill_vals)`` sorted by
        row.  ``spill_rows[k]`` indexes the *packed row set* (position in
        the ``rows`` argument of ``from_csr_rows``), so consumers apply the
        spill with one scatter-add after the dense ELL body pass.

    Total storage is ``n_rows * width + n_spill`` value slots, bounded by
    the typical-degree mass instead of the max degree — the SpArch-style
    condensed representation this repo's power-law workloads need.
    """

    cols: np.ndarray        # int32 (n_rows, width) body, pad col 0 / val 0
    vals: np.ndarray        # float (n_rows, width)
    spill_rows: np.ndarray  # int32 (n_spill,) packed-row index of the entry
    spill_cols: np.ndarray  # int32 (n_spill,)
    spill_vals: np.ndarray  # float (n_spill,)

    @property
    def width(self) -> int:
        return int(self.cols.shape[1])

    @property
    def n_spill(self) -> int:
        return int(self.spill_rows.shape[0])

    def packed_elements(self) -> int:
        """Value slots the format stores (body incl. padding + spill)."""
        return int(self.cols.size + self.spill_rows.size)

    @staticmethod
    def from_csr_rows(a: CSR, rows: np.ndarray,
                      cap: int | None = None) -> "HybridELL":
        """Pack ``rows`` of ``a`` with body width ``min(cap, max_deg)``.

        ``cap=None`` derives the traffic-optimal cap from the rows' own
        degree distribution.  O(nnz) — same flat scatter as ``TileELL`` with
        one extra mask splitting body slots from spill entries."""
        rows = np.asarray(rows, dtype=np.int64)
        flat, lens = csr_gather_rows(a, rows)
        if cap is None:
            cap = hybrid_width_cap(lens)
        w_max = int(lens.max()) if rows.size else 1
        w = max(min(int(cap), max(w_max, 1)), 1)
        cols = np.zeros((rows.shape[0], w), dtype=np.int32)
        vals = np.zeros((rows.shape[0], w), dtype=np.float64)
        if not flat.size:
            return HybridELL(cols, vals, np.zeros(0, np.int32),
                             np.zeros(0, np.int32), np.zeros(0, np.float64))
        r, k = ell_slot_coords(lens)
        body = k < w
        cols[r[body], k[body]] = a.indices[flat[body]]
        vals[r[body], k[body]] = a.data[flat[body]]
        sp = ~body
        return HybridELL(
            cols=cols, vals=vals,
            spill_rows=r[sp].astype(np.int32),
            spill_cols=a.indices[flat[sp]].astype(np.int32),
            spill_vals=a.data[flat[sp]].astype(np.float64))


def block_csr_pattern(a: CSR, block: int) -> CSR:
    """Collapse a CSR matrix to its block-level sparsity pattern.

    Returns a CSR over (ceil(n/block) x ceil(m/block)) block grid where
    data[k] = number of scalar nonzeros inside block k.  This is the DAG the
    TPU-side scheduler runs on (DESIGN.md §2: block granularity).
    """
    nb_rows = -(-a.n_rows // block)
    nb_cols = -(-a.n_cols // block)
    rows = np.repeat(np.arange(a.n_rows, dtype=np.int64), np.diff(a.indptr))
    brows = (rows // block).astype(np.int64)
    bcols = (a.indices.astype(np.int64) // block)
    key = brows * nb_cols + bcols
    uniq, counts = np.unique(key, return_counts=True)
    urows = (uniq // nb_cols).astype(np.int32)
    ucols = (uniq % nb_cols).astype(np.int32)
    indptr = np.zeros(nb_rows + 1, dtype=np.int32)
    np.add.at(indptr, urows + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return CSR(nb_rows, nb_cols, indptr, ucols, counts.astype(np.float64))


def block_diag_csr(mats, *, row_sizes=None, col_sizes=None) -> CSR:
    """Stack CSR matrices block-diagonally into one CSR.

    Block ``r`` occupies rows ``[sum(row_sizes[:r]), ...)`` and columns
    ``[sum(col_sizes[:r]), ...)``; size overrides larger than a block's own
    shape pad it with empty rows / never-referenced columns (the hetero
    fusion path passes a square pitch per relation so row and column
    offsets coincide and the stack stays square).  O(total nnz), one
    concatenation per array — no COO round-trip.
    """
    mats = list(mats)
    if not mats:
        raise ValueError("block_diag_csr needs at least one matrix")
    row_sizes = ([m.n_rows for m in mats] if row_sizes is None
                 else [int(s) for s in row_sizes])
    col_sizes = ([m.n_cols for m in mats] if col_sizes is None
                 else [int(s) for s in col_sizes])
    if len(row_sizes) != len(mats) or len(col_sizes) != len(mats):
        raise ValueError("row_sizes/col_sizes must match the matrix count")
    n_rows, n_cols = sum(row_sizes), sum(col_sizes)
    indptr = np.zeros(n_rows + 1, dtype=np.int64)
    idx_parts, data_parts = [], []
    row_off = col_off = nnz = 0
    for m, rs, cs in zip(mats, row_sizes, col_sizes):
        if rs < m.n_rows or cs < m.n_cols:
            raise ValueError(f"block size ({rs}, {cs}) smaller than matrix "
                             f"({m.n_rows}, {m.n_cols})")
        indptr[row_off + 1:row_off + m.n_rows + 1] = nnz + m.indptr[1:]
        indptr[row_off + m.n_rows + 1:row_off + rs + 1] = nnz + m.indptr[-1]
        idx_parts.append(m.indices.astype(np.int64) + col_off)
        data_parts.append(m.data)
        nnz += m.nnz
        row_off += rs
        col_off += cs
    return CSR(n_rows, n_cols, indptr.astype(np.int32),
               np.concatenate(idx_parts).astype(np.int32),
               np.concatenate(data_parts))


@dataclasses.dataclass(frozen=True)
class TileELL:
    """Padded ELL layout for a set of CSR rows, static-shape for XLA.

    Each of n_rows has up to `width` (col, val) slots; padding uses col=0,
    val=0 so padded slots contribute nothing.
    """

    cols: np.ndarray  # int32 (n_rows, width)
    vals: np.ndarray  # float (n_rows, width)

    @staticmethod
    def from_csr_rows(a: CSR, rows: np.ndarray, width: int | None = None) -> "TileELL":
        rows = np.asarray(rows)
        counts = (a.indptr[rows + 1] - a.indptr[rows]).astype(np.int64)
        w = int(counts.max()) if width is None and rows.size else (width or 1)
        w = max(w, 1)
        cols = np.zeros((rows.shape[0], w), dtype=np.int32)
        vals = np.zeros((rows.shape[0], w), dtype=np.float64)
        flat, lens = csr_gather_rows(a, rows)
        if flat.size:
            r, k = ell_slot_coords(lens)
            keep = k < w                       # explicit width may truncate
            r, k, flat = r[keep], k[keep], flat[keep]
            cols[r, k] = a.indices[flat]
            vals[r, k] = a.data[flat]
        return TileELL(cols=cols, vals=vals)
