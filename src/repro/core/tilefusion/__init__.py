"""Tile fusion — the paper's contribution as a composable JAX module.

``api.tile_fused_matmul`` is the one fused-matmul entrypoint (inspector
cache + backend dispatch); the submodules below are its building blocks.
"""
from .cost_model import (DEFAULT_CPU_CACHE_BYTES, DEFAULT_VMEM_BUDGET_BYTES,
                         tile_cost_bytes, tile_cost_elements,
                         tile_costs_batch)
from .scheduler import (Schedule, Tile, balanced_contiguous_partition,
                        build_schedule, fused_compute_ratio)
from .schedule import DeviceSchedule, to_device_schedule
from .sharded import ShardedSchedule, build_sharded_schedule, mesh_key
from . import api, fused_ops, fused_ref, hetero, serving, sharded
from .api import (clear_schedule_cache, get_schedule, schedule_cache_stats,
                  select_backend, tile_fused_matmul)
from .hetero import HeteroStack, hetero_fused_matmul, stack_adjacencies
from .spec import FusionSpec
from .serving import ServingTier

__all__ = [
    "Schedule", "Tile", "build_schedule", "fused_compute_ratio",
    "balanced_contiguous_partition",
    "DeviceSchedule", "to_device_schedule", "api", "fused_ops", "fused_ref",
    "ShardedSchedule", "build_sharded_schedule", "mesh_key", "sharded",
    "ServingTier", "serving",
    "HeteroStack", "hetero", "hetero_fused_matmul", "stack_adjacencies",
    "tile_fused_matmul", "get_schedule", "select_backend",
    "clear_schedule_cache", "schedule_cache_stats", "FusionSpec",
    "tile_cost_bytes", "tile_cost_elements", "tile_costs_batch",
    "DEFAULT_CPU_CACHE_BYTES", "DEFAULT_VMEM_BUDGET_BYTES",
]
