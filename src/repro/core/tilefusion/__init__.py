"""Tile fusion — the paper's contribution as a composable JAX module."""
from .cost_model import (DEFAULT_CPU_CACHE_BYTES, DEFAULT_VMEM_BUDGET_BYTES,
                         tile_cost_bytes, tile_cost_elements)
from .scheduler import Schedule, Tile, build_schedule, fused_compute_ratio
from .schedule import DeviceSchedule, to_device_schedule
from . import fused_ops, fused_ref

__all__ = [
    "Schedule", "Tile", "build_schedule", "fused_compute_ratio",
    "DeviceSchedule", "to_device_schedule", "fused_ops", "fused_ref",
    "tile_cost_bytes", "tile_cost_elements",
    "DEFAULT_CPU_CACHE_BYTES", "DEFAULT_VMEM_BUDGET_BYTES",
]
