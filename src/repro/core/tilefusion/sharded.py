"""Sharded tile-fusion executors — the wavefront-0 tile grid over a mesh.

The paper balances locality against "sufficient workload for cores" on one
shared-memory node; this module lifts the same tradeoff to a device mesh.
The unit of distribution is the inspector's *fused schedule* (keeping the
fused tile intact is what makes wavefront 0 communication-free): the
wavefront-0 tile grid is partitioned row-block over the mesh's row axis,
with contiguous tile groups balanced by their Eq-3 cost
(``scheduler.balanced_contiguous_partition``) so every shard streams
comparable fused-tile bytes.

Execution model (per shard, under the ``models/sharding.py`` shard_map
shim):

  wavefront 0   each shard computes the D1 rows of its own tiles (GeMM or
                hybrid-ELL op-1 SpMM) and its fused second-op rows — zero
                communication, by the fusion criterion every dependency is
                tile-local and therefore shard-local.
  halo          each shard contributes the wavefront-1 dependency rows
                (``DeviceSchedule.wf1_dep_rows``) it owns, one
                ``all_gather`` over the row axis assembles the halo table
                on every device (``cost_model.shard_comm_model`` prices
                this against full-D1 replication).
  wavefront 1   wavefront-1 tiles and spill lanes are partitioned over
                shards (tiles cost-balanced; spill lanes co-located with
                the shard that owns their target D row), reading the halo
                table.

Two output-combine strategies, chosen by ``cost_model.shard_comm_model``
(``combine_bytes`` vs ``combine_bytes_reduce_scatter``) or forced by the
caller:

  ``"psum"``            every shard scatters its partial into a full
                        ``(n_j, c_col)`` buffer and one all-reduce
                        combines them — simple, but the full D crosses
                        the wire to every device.
  ``"reduce_scatter"``  the row-remapped combine: D rows are permuted so
                        each shard *owns* one contiguous block (its wf0
                        fused rows + its wf1 tile rows; spill lanes are
                        co-located with their target row's owner, so the
                        per-shard partials are owner-disjoint by
                        construction).  Each shard emits only its own
                        ``(rows_per_shard, c_col)`` block — the combine
                        itself moves zero bytes; a block crosses the wire
                        once, when the caller consumes the output through
                        the inverse row permutation (``out_perm``).

2-D meshes (the replicated 1.5D layout of Bharadwaj et al.): the leading
mesh axis keeps the row-block partition above; the trailing axis splits
the dense operand's *columns* into ``n_repl`` independent replica groups.
The sparse operand, B, and the schedule's index arrays are replicated
across the replica axis (the memory cost) while every communication term
— halo, combine — carries only ``c_col / n_repl`` columns (the
communication saving).  ``cost_model.choose_mesh_layout`` weighs the two
against flattening the whole mesh into row shards (pure 1-D).

Static shapes: per-shard tile counts differ, so the stacked arrays are
padded to the max tiles/rows per shard; padded slots reuse the schedule's
own conventions (row ``n_j`` — or ``rows_per_shard`` for the local output
blocks — scatter-dropped, col 0 / val 0 no-ops).

The builder requires a *uniform* wavefront-0 grid (``uniform_split=True``,
the dispatch default) — the same precondition as the Pallas kernels — so a
tile index is a D1 row-block index and the halo owner map is one
``searchsorted``.  Non-uniform schedules return ``None`` and the dispatch
falls back to single-device execution, as it does on a trivial mesh.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.formats import CSR, csr_content_digest
from . import cost_model, fused_ops
from .schedule import DeviceSchedule
from .scheduler import Schedule, balanced_contiguous_partition, \
    resolve_mesh_layout

#: Valid output-combine strategies (plus "auto" at the dispatch layer).
COMBINE_MODES = ("psum", "reduce_scatter")


def mesh_key(mesh) -> tuple | None:
    """Hashable cache-key component for a mesh: axis names + shape.

    ``None`` for ``mesh=None`` *and* for single-device meshes — a trivial
    mesh dispatches identically to no mesh, so the two must share cache
    entries."""
    if mesh is None:
        return None
    shape = tuple(int(s) for s in np.shape(mesh.devices))
    if int(np.prod(shape)) <= 1:
        return None
    return (tuple(str(n) for n in mesh.axis_names), shape)


@dataclasses.dataclass(frozen=True)
class ShardedSchedule:
    """Per-shard restructuring of a uniform ``DeviceSchedule``.

    All stacked arrays carry the shard dimension flattened into their
    leading axis (``S * per_shard``) so ``shard_map`` with ``P(axes)``
    hands each device exactly its block."""

    n_shards: int                 # row-block shards (the mesh's row axis)
    n_repl: int                   # column replicas (1 = pure 1-D layout)
    combine: str                  # "psum" | "reduce_scatter"
    t_pad: int
    n_i: int
    n_j: int
    n_tiles0: int                 # global wavefront-0 tile count
    tiles_per_shard: int          # T0s (padded)
    tile_bounds: np.ndarray       # (S+1,) contiguous tile-index bounds
    tile_map: np.ndarray          # (S*T0s,) global tile id, pad = n_tiles0
    row_map: np.ndarray           # (S*T0s*t,) global padded D1 row, pad = 0
    # wavefront 0 (gathered from DeviceSchedule in shard order)
    j_rows0: np.ndarray           # (S*T0s, j0_max) global D rows, pad = n_j
    ell_cols0: np.ndarray         # (S*T0s, j0_max, w0) tile-local
    ell_vals0: np.ndarray
    # wavefront 1 (cols remapped to halo-table positions)
    wf1_per_shard: int            # T1s (padded; 0 = empty wavefront)
    j_rows1: np.ndarray           # (S*T1s, j1_max) pad = n_j
    ell_cols1: np.ndarray         # (S*T1s, j1_max, w1) halo positions
    ell_vals1: np.ndarray
    spill_per_shard: int          # L (padded)
    spill_rows1: np.ndarray       # (S*L,) global D rows, pad = n_j
    spill_cols1: np.ndarray       # (S*L,) halo positions, pad = 0
    spill_vals1: np.ndarray       # (S*L,) pad = 0
    # halo exchange
    halo_rows: np.ndarray         # (H,) sorted global D1 rows wf1 reads
    send_per_shard: int           # Hs (padded)
    send_local: np.ndarray        # (S*Hs,) shard-local padded row, pad = 0
    send_pos: np.ndarray          # (S, Hs) halo-table position, pad = H
    # output ownership (the reduce-scatter row remap): every D row is
    # owned by the one shard that writes it — wf0 fused rows by their
    # tile's shard, wf1 rows by their wf1 tile's shard
    rows_per_shard: int           # R: padded owned rows per shard
    out_perm: np.ndarray          # (n_j,) permuted block position of row j
    out_rows0: np.ndarray         # (S*T0s, j0_max) shard-local out, pad = R
    out_rows1: np.ndarray         # (S*T1s, j1_max) shard-local out, pad = R
    out_spill: np.ndarray         # (S*L,) shard-local out, pad = R
    #: ``cost_model.shard_comm_model`` of this partition (halo all-gather
    #: bytes vs full-D1 replication; psum vs reduce-scatter combine) —
    #: surfaced through the schedule entry's traffic model.
    comm_model: dict = dataclasses.field(default_factory=dict)

    @property
    def halo_size(self) -> int:
        return int(self.halo_rows.shape[0])

    @property
    def layout(self) -> str:
        """"1d" (row shards only) or "1.5d" (column replicas too)."""
        return "1d" if self.n_repl == 1 else "1.5d"

    def shard_tile_counts(self) -> np.ndarray:
        """Real (unpadded) wavefront-0 tiles per shard — the balance the
        Eq-3 partition produced, pinned by tests."""
        return np.diff(self.tile_bounds)

    def shard_owned_counts(self) -> np.ndarray:
        """Real (unpadded) owned output rows per shard — the row blocks of
        the reduce-scatter combine, disjoint and exhaustive over D."""
        pos = np.sort(self.out_perm)
        bounds = np.searchsorted(pos, np.arange(self.n_shards + 1)
                                 * self.rows_per_shard)
        return np.diff(bounds)


def _pad_gather(src: np.ndarray, idx: np.ndarray, pad_value) -> np.ndarray:
    """Gather ``src[idx]`` where ``idx == src.shape[0]`` selects a padding
    element filled with ``pad_value``."""
    pad = np.full((1,) + src.shape[1:], pad_value, dtype=src.dtype)
    return np.concatenate([src, pad], axis=0)[idx]


def _remap_to_halo(cols: np.ndarray, halo_rows: np.ndarray) -> np.ndarray:
    """Global D1 rows -> positions in the halo table; rows not in the halo
    (only possible for zero-valued slots, which the halo set filters) map
    to position 0 where the zero value makes the read a no-op."""
    if halo_rows.size == 0:
        return np.zeros_like(cols)
    pos = np.searchsorted(halo_rows, cols)
    pos = np.minimum(pos, halo_rows.size - 1)
    hit = halo_rows[pos] == cols
    return np.where(hit, pos, 0).astype(np.int32)


def _owner_of_tiles(bounds: np.ndarray, tile_ids: np.ndarray,
                    n_shards: int) -> np.ndarray:
    """Owning shard of each tile id under contiguous ``bounds``."""
    own = np.searchsorted(bounds, tile_ids, side="right") - 1
    return np.clip(own, 0, n_shards - 1)


def _pack_by_group(owners: np.ndarray, n_groups: int) -> tuple:
    """Pack items into equal-stride per-group slots — the one packing rule
    behind the halo send tables, the output-ownership permutation, and the
    spill-lane co-location.

    Returns ``(counts, stride, order, dst)``: item ``order[k]`` lands at
    flat slot ``dst[k] = group * stride + rank_within_group`` where
    ``stride = max(counts, 1)`` (so every group's block is padded to the
    same height) and ``order`` walks the items in stable group order."""
    owners = np.asarray(owners, dtype=np.int64)
    counts = np.bincount(owners, minlength=n_groups)
    stride = max(int(counts.max()) if owners.size else 0, 1)
    order = np.argsort(owners, kind="stable")
    offsets = np.concatenate([[0], np.cumsum(counts)])
    dst = (np.repeat(np.arange(n_groups, dtype=np.int64), counts) * stride
           + np.arange(owners.size, dtype=np.int64)
           - np.repeat(offsets[:-1], counts))
    return counts, stride, order, dst


def _local_out_rows(stacked_rows: np.ndarray, shard_of: np.ndarray,
                    pos_of_row: np.ndarray, n_j: int,
                    r_per: int) -> np.ndarray:
    """Shard-local output positions for a stacked global-row array: real
    rows map to ``pos_of_row - shard * R`` (in [0, R) — every row in a
    shard's stack is owned by that shard), pad slots map to ``R``
    (scatter-dropped)."""
    if stacked_rows.size == 0 or n_j == 0:
        return np.full(stacked_rows.shape, r_per, np.int32)
    real = stacked_rows < n_j
    safe = np.minimum(stacked_rows, max(n_j - 1, 0))
    loc = pos_of_row[safe] - shard_of.reshape(
        shard_of.shape + (1,) * (stacked_rows.ndim - shard_of.ndim)) * r_per
    return np.where(real, loc, r_per).astype(np.int32)


def build_sharded_schedule(a: CSR, sched: Schedule, dsched: DeviceSchedule,
                           mesh_shape, *, b_col: int, c_col: int,
                           b_is_sparse: bool,
                           width_cap: int | None = None,
                           layout: str = "1d",
                           combine: str = "auto",
                           dtype_bytes: int = 4):
    """Partition a uniform schedule over a mesh shape (an int or a shape
    tuple) under a layout — ``scheduler.resolve_mesh_layout`` is the one
    place the shape becomes (row shards × column replicas).

    ``combine`` picks the output-combine strategy (``"auto"`` defers to
    ``shard_comm_model``'s byte pricing).  Returns ``None`` when the
    schedule is not a uniform wavefront-0 grid (the caller falls back to
    single-device dispatch)."""
    if combine not in COMBINE_MODES + ("auto",):
        raise ValueError(f"combine={combine!r}; expected one of "
                         f"{COMBINE_MODES + ('auto',)}")
    s_n, n_repl = resolve_mesh_layout(mesh_shape, layout)
    if s_n * n_repl <= 1 or not fused_ops._is_uniform(dsched):
        return None
    t = dsched.t_pad
    n_t = dsched.n_tiles0
    n_j = dsched.n_j
    wf0, wf1 = sched.wavefronts

    # ---- wavefront 0: Eq-3-balanced contiguous tile partition over the
    # mesh's row axis (replica groups share tiles) ----
    costs0 = cost_model.tile_costs_batch(
        a, [tl.i_start for tl in wf0], [tl.i_end for tl in wf0],
        [tl.j_rows for tl in wf0], b_col, c_col, b_is_sparse,
        width_cap=width_cap)
    tile_bounds = balanced_contiguous_partition(costs0, s_n)
    per = np.diff(tile_bounds)
    t0s = max(int(per.max()) if per.size else 0, 1)
    tile_map = np.full((s_n, t0s), n_t, dtype=np.int64)
    for s in range(s_n):
        ids = np.arange(tile_bounds[s], tile_bounds[s + 1], dtype=np.int64)
        tile_map[s, : ids.size] = ids
    tile_map = tile_map.reshape(-1)

    j_rows0 = _pad_gather(dsched.j_rows0, tile_map, n_j)
    ell_cols0 = _pad_gather(dsched.ell_cols0, tile_map, 0)
    ell_vals0 = _pad_gather(dsched.ell_vals0, tile_map, 0)

    valid = tile_map < n_t
    row_map = (np.where(valid, tile_map, 0)[:, None] * t
               + np.arange(t, dtype=np.int64)[None, :])
    row_map = np.where(valid[:, None], row_map, 0).reshape(-1)

    # ---- halo: owner of each wavefront-1 dependency row ----
    halo_rows = dsched.wf1_dep_rows()
    h = int(halo_rows.shape[0])
    row_bounds = tile_bounds * t
    if h:
        owner = np.searchsorted(row_bounds, halo_rows, side="right") - 1
        owner = np.clip(owner, 0, s_n - 1)
        # halo_rows is sorted and ownership is contiguous, so the stable
        # group order is the identity: slot = rank within the shard's run
        _, hs, h_ord, h_dst = _pack_by_group(owner, s_n)
        send_local = np.zeros(s_n * hs, dtype=np.int32)
        send_pos = np.full(s_n * hs, h, dtype=np.int32)
        send_local[h_dst] = (halo_rows - row_bounds[owner]).astype(
            np.int32)[h_ord]
        send_pos[h_dst] = np.arange(h, dtype=np.int32)[h_ord]
        send_pos = send_pos.reshape(s_n, hs)
    else:
        hs = 1
        send_local = np.zeros(s_n * 1, dtype=np.int32)
        send_pos = np.full((s_n, 1), 0, dtype=np.int32)

    # ---- wavefront 1: cost-balanced tile partition + halo remap ----
    n_t1 = dsched.n_tiles1
    if n_t1:
        costs1 = cost_model.tile_costs_batch(
            a, np.zeros(n_t1, np.int64), np.zeros(n_t1, np.int64),
            [tl.j_rows for tl in wf1], b_col, c_col, b_is_sparse,
            width_cap=width_cap)
        bounds1 = balanced_contiguous_partition(costs1, s_n)
        per1 = np.diff(bounds1)
        t1s = max(int(per1.max()), 1)
        tmap1 = np.full((s_n, t1s), n_t1, dtype=np.int64)
        for s in range(s_n):
            ids = np.arange(bounds1[s], bounds1[s + 1], dtype=np.int64)
            tmap1[s, : ids.size] = ids
        tmap1 = tmap1.reshape(-1)
        j_rows1 = _pad_gather(dsched.j_rows1, tmap1, n_j)
        cols1 = _pad_gather(dsched.ell_cols1, tmap1, 0)
        vals1 = _pad_gather(dsched.ell_vals1, tmap1, 0)
        cols1 = _remap_to_halo(cols1, halo_rows)
    else:
        bounds1 = np.zeros(s_n + 1, dtype=np.int64)
        t1s = 0
        j_rows1 = np.full((0, 1), n_j, dtype=np.int32)
        cols1 = np.zeros((0, 1, 1), dtype=np.int32)
        vals1 = np.zeros((0, 1, 1), dtype=np.float32)

    # ---- output ownership: row -> owning shard -> permuted position ----
    # Every D row is written by exactly one tile (Schedule.validate), so
    # the per-shard write sets are disjoint and exhaustive: wf0 fused rows
    # belong to their tile's shard, wf1 rows to their wf1 tile's shard.
    own_row = np.zeros(max(n_j, 1), dtype=np.int64)
    sizes0 = np.asarray([tl.n_j for tl in wf0], dtype=np.int64)
    if sizes0.sum():
        j0_all = np.concatenate([tl.j_rows for tl in wf0]).astype(np.int64)
        t0_of = np.repeat(np.arange(len(wf0), dtype=np.int64), sizes0)
        own_row[j0_all] = _owner_of_tiles(tile_bounds, t0_of, s_n)
    if n_t1:
        sizes1 = np.asarray([tl.n_j for tl in wf1], dtype=np.int64)
        j1_all = np.concatenate([tl.j_rows for tl in wf1]).astype(np.int64)
        t1_of = np.repeat(np.arange(n_t1, dtype=np.int64), sizes1)
        own_row[j1_all] = _owner_of_tiles(bounds1, t1_of, s_n)
    own_row = own_row[:n_j]
    _, r_per, o_ord, o_dst = _pack_by_group(own_row, s_n)
    pos_of_row = np.empty(n_j, dtype=np.int64)
    pos_of_row[o_ord] = o_dst

    shard_of0 = np.repeat(np.arange(s_n, dtype=np.int64), t0s)
    out_rows0 = _local_out_rows(j_rows0, shard_of0, pos_of_row, n_j, r_per)
    if t1s:
        shard_of1 = np.repeat(np.arange(s_n, dtype=np.int64), t1s)
        out_rows1 = _local_out_rows(j_rows1, shard_of1, pos_of_row, n_j,
                                    r_per)
    else:
        out_rows1 = np.full(j_rows1.shape, r_per, dtype=np.int32)

    # ---- spill lanes: co-located with their target row's owner (the
    # shard whose wf1 tile wrote the body, so the reduce-scatter partials
    # stay owner-disjoint and the body .set always precedes the .add) ----
    n_sp = int(dsched.spill_rows1.shape[0])
    if n_sp:
        sp_remap = _remap_to_halo(dsched.spill_cols1, halo_rows)
        sp_owner = own_row[dsched.spill_rows1.astype(np.int64)]
        _, sp_l, sp_order, dst = _pack_by_group(sp_owner, s_n)
        spill_rows = np.full(s_n * sp_l, n_j, np.int32)
        spill_cols = np.zeros(s_n * sp_l, np.int32)
        spill_vals = np.zeros(s_n * sp_l, np.float32)
        spill_rows[dst] = dsched.spill_rows1[sp_order]
        spill_cols[dst] = sp_remap[sp_order]
        spill_vals[dst] = dsched.spill_vals1[sp_order]
        out_spill = np.full(s_n * sp_l, r_per, np.int32)
        out_spill[dst] = (pos_of_row[dsched.spill_rows1[sp_order].astype(
            np.int64)] - sp_owner[sp_order] * r_per).astype(np.int32)
    else:
        sp_l = 0
        spill_rows = np.zeros(0, np.int32)
        spill_cols = np.zeros(0, np.int32)
        spill_vals = np.zeros(0, np.float32)
        out_spill = np.zeros(0, np.int32)

    comm = cost_model.shard_comm_model(s_n, h, dsched.n_i, c_col,
                                       n_j=n_j, n_repl=n_repl,
                                       combine_rows=s_n * r_per,
                                       dtype_bytes=dtype_bytes)
    mode = comm["combine"] if combine == "auto" else combine
    return ShardedSchedule(
        n_shards=s_n, n_repl=n_repl, combine=mode,
        t_pad=t, n_i=dsched.n_i, n_j=n_j, n_tiles0=n_t,
        tiles_per_shard=t0s, tile_bounds=tile_bounds, tile_map=tile_map,
        row_map=row_map,
        j_rows0=j_rows0, ell_cols0=ell_cols0, ell_vals0=ell_vals0,
        wf1_per_shard=t1s, j_rows1=j_rows1, ell_cols1=cols1,
        ell_vals1=vals1,
        spill_per_shard=sp_l, spill_rows1=spill_rows,
        spill_cols1=spill_cols, spill_vals1=spill_vals,
        halo_rows=halo_rows, send_per_shard=hs,
        send_local=send_local.reshape(-1), send_pos=send_pos,
        rows_per_shard=r_per, out_perm=pos_of_row,
        out_rows0=out_rows0, out_rows1=out_rows1, out_spill=out_spill,
        comm_model=comm,
    )


# --------------------------------------------------------------------------
# Executors
# --------------------------------------------------------------------------
def _shard_executor(shard: ShardedSchedule, mesh, kind: str):
    """Build (and memoize per (mesh, kind)) the jitted shard_map executor.

    The schedule's index arrays are closed over as constants — they are
    part of the (cached) schedule, so jit's tracing cache stays hot across
    calls with the same operand shapes."""
    memo = getattr(shard, "_exec_memo", None)
    if memo is None:
        memo = {}
        object.__setattr__(shard, "_exec_memo", memo)
    key = (mesh, kind)
    fn = memo.get(key)
    if fn is not None:
        return fn

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ...models.sharding import mesh_row_repl_axes, shard_map

    row_axes, repl_axes = mesh_row_repl_axes(mesh, shard.layout)
    mesh_sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
    if (int(np.prod([mesh_sizes[ax] for ax in row_axes])) != shard.n_shards
            or int(np.prod([mesh_sizes[ax] for ax in repl_axes] or [1]))
            != shard.n_repl):
        raise ValueError(
            f"mesh shape {dict(mesh_sizes)} does not match the schedule's "
            f"{shard.n_shards}x{shard.n_repl} ({shard.layout}) partition")
    sh = P(row_axes)        # leading dim carries the row-shard axis
    rep = P(None, repl_axes) if repl_axes else P()       # column replicas
    sh_col = P(row_axes, repl_axes) if repl_axes else P(row_axes)
    reduce_scatter = shard.combine == "reduce_scatter"
    t, t0s = shard.t_pad, shard.tiles_per_shard
    t1s, sp_l = shard.wf1_per_shard, shard.spill_per_shard
    n_j, h = shard.n_j, shard.halo_size
    r_per = shard.rows_per_shard
    # local output-buffer height and scatter targets per combine mode: the
    # psum arm scatters global D rows into a full (n_j, cc) partial and
    # all-reduces; the reduce-scatter arm scatters shard-local owned
    # positions into the shard's own (R, cc) block and emits it directly
    out_n = r_per if reduce_scatter else n_j
    rows0_np = shard.out_rows0 if reduce_scatter else shard.j_rows0
    rows1_np = shard.out_rows1 if reduce_scatter else shard.j_rows1
    srows_np = shard.out_spill if reduce_scatter else shard.spill_rows1
    # index arrays are dtype-independent: convert (and upload) once at
    # build time, not per call — only the value arrays depend on the
    # operands' dtype and get their own tiny per-dtype memo below
    send_pos = jnp.asarray(shard.send_pos)           # replicated constant
    idx_args = (jnp.asarray(rows0_np), jnp.asarray(shard.ell_cols0),
                jnp.asarray(rows1_np), jnp.asarray(shard.ell_cols1),
                jnp.asarray(srows_np),
                jnp.asarray(shard.spill_cols1),
                jnp.asarray(shard.send_local))
    vals_by_dtype: dict = {}

    def wf1_and_combine(d, d1_local, rows1_s, cols1_s, vals1_s,
                        srows_s, scols_s, svals_s, send_local_s):
        """Halo all-gather (row axis only) + this shard's wavefront-1
        share, then the combine: psum over the row axis, or — when the
        partials are owner-disjoint — emit the shard's own block."""
        c_col = d.shape[1]
        if h:
            contrib = d1_local[send_local_s]              # (Hs, c_col)
            gathered = jax.lax.all_gather(contrib, row_axes)
            halo = jnp.zeros((h, c_col), d.dtype).at[
                send_pos.reshape(-1)].set(
                gathered.reshape(-1, c_col), mode="drop")
            if t1s:
                rows1 = fused_ops._ell_rows(cols1_s, vals1_s, halo)
                d = d.at[rows1_s.reshape(-1)].set(
                    rows1.reshape(-1, c_col), mode="drop")
            if sp_l:
                d = d.at[srows_s].add(
                    svals_s.astype(d.dtype)[:, None] * halo[scols_s])
        if reduce_scatter:
            return d
        return jax.lax.psum(d, row_axes)

    def per_shard_gemm(b_blk, c, rows0_s, cols0_s, vals0_s, rows1_s,
                       cols1_s, vals1_s, srows_s, scols_s, svals_s,
                       send_local_s):
        c_col = c.shape[1]
        d1_t = b_blk.reshape(t0s, t, -1) @ c              # (T0s, t, c_col)
        rows0 = jax.vmap(fused_ops._ell_rows)(cols0_s, vals0_s, d1_t)
        d = jnp.zeros((out_n, c_col), c.dtype).at[
            rows0_s.reshape(-1)].set(rows0.reshape(-1, c_col),
                                     mode="drop")
        return wf1_and_combine(d, d1_t.reshape(t0s * t, c_col), rows1_s,
                               cols1_s, vals1_s, srows_s, scols_s, svals_s,
                               send_local_s)

    def per_shard_spmm(o_cols_s, o_vals_s, d1_spill_s, c, rows0_s,
                       cols0_s, vals0_s, rows1_s, cols1_s, vals1_s,
                       srows_s, scols_s, svals_s, send_local_s):
        c_col = c.shape[1]
        # op-1 SpMM per tile: hybrid ELL body over replicated C + the
        # tile's pre-accumulated spill delta
        d1_t = fused_ops._ell_rows(o_cols_s, o_vals_s, c) \
            + d1_spill_s.reshape(t0s, t, c_col)
        rows0 = jax.vmap(fused_ops._ell_rows)(cols0_s, vals0_s, d1_t)
        d = jnp.zeros((out_n, c_col), c.dtype).at[
            rows0_s.reshape(-1)].set(rows0.reshape(-1, c_col),
                                     mode="drop")
        return wf1_and_combine(d, d1_t.reshape(t0s * t, c_col), rows1_s,
                               cols1_s, vals1_s, srows_s, scols_s, svals_s,
                               send_local_s)

    if kind == "gemm":
        body = per_shard_gemm
        lead_specs = (sh, rep)
    else:
        body = per_shard_spmm
        lead_specs = (sh, sh, sh_col, rep)
    # operand specs: leading op inputs, then the schedule's 10 stacked
    # index arrays (all sharded over the row axis on dim 0)
    in_specs = lead_specs + (sh,) * 10
    out_specs = sh_col if reduce_scatter else rep
    mapped = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)
    fn = jax.jit(mapped)

    def run(*operands):
        dtype = operands[-1].dtype                  # C is the last operand
        vals = vals_by_dtype.get(dtype)
        if vals is None:
            vals = (jnp.asarray(shard.ell_vals0, dtype),
                    jnp.asarray(shard.ell_vals1, dtype),
                    jnp.asarray(shard.spill_vals1, dtype))
            vals_by_dtype[dtype] = vals
        rows0, cols0, rows1_a, cols1_a, srows, scols, send_local = \
            idx_args
        args = operands + (rows0, cols0, vals[0], rows1_a, cols1_a,
                           vals[1], srows, scols, vals[2], send_local)
        return fn(*args)

    memo[key] = run
    return run


def _device_const(shard: ShardedSchedule, attr: str):
    """A ShardedSchedule index array as a device array, uploaded once per
    schedule (memoized on the frozen instance)."""
    import jax.numpy as jnp
    cache_attr = f"_{attr}_jax"
    arr = getattr(shard, cache_attr, None)
    if arr is None:
        arr = jnp.asarray(getattr(shard, attr))
        object.__setattr__(shard, cache_attr, arr)
    return arr


def _pad_cols(c, n_repl: int):
    """Pad C's trailing dim to a multiple of ``n_repl`` so the replica
    axis splits it evenly; callers slice the padding back off the output."""
    import jax.numpy as jnp
    cc = int(c.shape[1])
    cc_pad = -(-cc // n_repl) * n_repl
    if cc_pad != cc:
        c = jnp.pad(c, ((0, 0), (0, cc_pad - cc)))
    return c, cc


def _finish(shard: ShardedSchedule, out, c_col: int):
    """Post-executor output assembly: the reduce-scatter arm's permuted
    owner blocks are mapped back to D's row order (one gather — each
    owned block crosses the wire once, the byte count
    ``combine_bytes_reduce_scatter`` prices), and column padding from the
    replica split is sliced off."""
    if shard.combine == "reduce_scatter":
        out = out[_device_const(shard, "out_perm")]
    if int(out.shape[1]) != c_col:
        out = out[:, :c_col]
    return out


def sharded_gemm_spmm(shard: ShardedSchedule, mesh, b, c):
    """GeMM-SpMM over the mesh: B row-blocks follow the tile partition."""
    import jax.numpy as jnp
    b = jnp.asarray(b)
    if b.shape[0] != shard.n_i:
        raise ValueError(f"b has {b.shape[0]} rows, schedule expects "
                         f"{shard.n_i}")
    c, c_col = _pad_cols(jnp.asarray(c), shard.n_repl)
    n_pad = shard.n_tiles0 * shard.t_pad
    b_pad = jnp.pad(b, ((0, n_pad - b.shape[0]), (0, 0)))
    b_blk = b_pad[_device_const(shard, "row_map")]    # (S*T0s*t, b_col)
    run = _shard_executor(shard, mesh, "gemm")
    return _finish(shard, run(b_blk, c), c_col)


def _op1_sharded(shard: ShardedSchedule, dsched: DeviceSchedule, a1: CSR,
                 dtype):
    """Shard-ordered op-1 hybrid pack as *device* arrays, memoized per
    (a1 content, cap, dtype) like ``fused_ops._op1_ell`` itself — the
    O(nnz) repack *and* the host-to-device upload happen once per
    schedule, not once per call (the op-1 arrays are the largest operands
    in the problem)."""
    import jax.numpy as jnp
    cap = dsched.width_cap
    memo_key = (csr_content_digest(a1),
                None if cap is None else int(cap), str(dtype))
    memo = getattr(shard, "_op1_memo", None)
    if memo is not None and memo[0] == memo_key:
        return memo[1]
    o_cols, o_vals, spill_flat, spill_cols, spill_vals = fused_ops._op1_ell(
        a1, dsched, width_cap=cap)
    # per-tile arrays -> shard order (pad tiles are zero ELL, a no-op)
    o_cols_s = _pad_gather(o_cols, shard.tile_map, 0)
    o_vals_s = _pad_gather(o_vals, shard.tile_map, 0)
    packed = (jnp.asarray(o_cols_s), jnp.asarray(o_vals_s, dtype),
              int(spill_flat.size), jnp.asarray(spill_flat),
              jnp.asarray(spill_cols), jnp.asarray(spill_vals, dtype))
    object.__setattr__(shard, "_op1_memo", (memo_key, packed))
    return packed


def sharded_spmm_spmm(shard: ShardedSchedule, dsched: DeviceSchedule,
                      mesh, a1: CSR, c):
    """SpMM-SpMM over the mesh: per-shard op-1 hybrid ELL against a
    replicated C; the op-1 spill delta is scattered globally then gathered
    into shard order with the same row map as the GeMM path's B blocks."""
    import jax.numpy as jnp
    c = jnp.asarray(c)
    if a1.n_rows != shard.n_i:
        raise ValueError(f"op-1 has {a1.n_rows} rows, schedule expects "
                         f"{shard.n_i}")
    if c.shape[0] != a1.n_cols:
        raise ValueError(f"c has {c.shape[0]} rows, op-1 has {a1.n_cols} "
                         f"columns")
    c, c_col = _pad_cols(c, shard.n_repl)
    cc_pad = c.shape[1]
    o_cols_s, o_vals_s, n_spill, spill_flat, spill_cols, spill_vals = \
        _op1_sharded(shard, dsched, a1, c.dtype)
    n_pad = shard.n_tiles0 * shard.t_pad
    d1_spill = jnp.zeros((n_pad, cc_pad), c.dtype)
    if n_spill:
        d1_spill = d1_spill.at[spill_flat].add(
            spill_vals.astype(c.dtype)[:, None] * c[spill_cols])
    d1_spill_blk = d1_spill[_device_const(shard, "row_map")]
    run = _shard_executor(shard, mesh, "spmm")
    return _finish(shard, run(o_cols_s, o_vals_s, d1_spill_blk, c), c_col)
