"""Sharded tile-fusion executors — the wavefront-0 tile grid over a mesh.

The paper balances locality against "sufficient workload for cores" on one
shared-memory node; this module lifts the same tradeoff to a device mesh.
The unit of distribution is the inspector's *fused schedule* (keeping the
fused tile intact is what makes wavefront 0 communication-free): the
wavefront-0 tile grid is partitioned row-block over the mesh's row axis,
with contiguous tile groups balanced by their Eq-3 cost
(``scheduler.balanced_contiguous_partition``) so every shard streams
comparable fused-tile bytes.

Execution model (per shard, under the ``models/sharding.py`` shard_map
shim):

  wavefront 0   each shard computes the D1 rows of its own tiles (GeMM or
                hybrid-ELL op-1 SpMM) and its fused second-op rows — zero
                communication, by the fusion criterion every dependency is
                tile-local and therefore shard-local.
  halo          each shard contributes the wavefront-1 dependency rows
                (``DeviceSchedule.wf1_dep_rows``) it owns, one
                ``all_gather`` over the row axis assembles the halo table
                on every device (``cost_model.shard_comm_model`` prices
                this against full-D1 replication).
  wavefront 1   wavefront-1 tiles and spill lanes are partitioned over
                shards (tiles cost-balanced; spill lanes co-located with
                the shard that owns their target D row), reading the halo
                table.

Two output-combine strategies, chosen by ``cost_model.shard_comm_model``
(``combine_bytes`` vs ``combine_bytes_reduce_scatter``) or forced by the
caller:

  ``"psum"``            every shard scatters its partial into a full
                        ``(n_j, c_col)`` buffer and one all-reduce
                        combines them — simple, but the full D crosses
                        the wire to every device.
  ``"reduce_scatter"``  the row-remapped combine: D rows are permuted so
                        each shard *owns* one contiguous block (its wf0
                        fused rows + its wf1 tile rows; spill lanes are
                        co-located with their target row's owner, so the
                        per-shard partials are owner-disjoint by
                        construction).  Each shard emits only its own
                        ``(rows_per_shard, c_col)`` block — the combine
                        itself moves zero bytes; a block crosses the wire
                        once, when the caller consumes the output through
                        the inverse row permutation (``out_perm``).

2-D meshes (the replicated 1.5D layout of Bharadwaj et al.): the leading
mesh axis keeps the row-block partition above; the trailing axis splits
the dense operand's *columns* into ``n_repl`` independent replica groups.
The sparse operand, B, and the schedule's index arrays are replicated
across the replica axis (the memory cost) while every communication term
— halo, combine — carries only ``c_col / n_repl`` columns (the
communication saving).  ``cost_model.choose_mesh_layout`` weighs the two
against flattening the whole mesh into row shards (pure 1-D).

3-D meshes (the 2.5D rung of the same ladder): axes past the second fold
into ``n_depth`` *depth layers* that replicate the wavefront-0 compute
(only layer 0's devices emit the wf0 fused rows — the depth combine
restores them everywhere) and split the wavefront-1 work: wf1 tiles and
spill lanes are partitioned over ``n_shards × n_depth`` groups, and each
depth layer assembles only *its own* halo table — the union of its
groups' dependency rows — with a row-axis all-gather.  That is the
staged exchange: ``n_depth`` leaf gathers run in parallel (each device
moves ~1/n_depth of the 1.5D halo share) and the depth-axis psum of the
partial outputs is the root combine.

Async overlap (``overlap=True`` / ``"auto"``): the halo all-gather is
issued *before* the main wavefront-0 body — each shard first recomputes
just its halo send rows' D1 values (a small duplicate-compute prologue:
``b[send] @ C`` on the GeMM path, the send rows' hybrid-ELL lanes on the
SpMM path), launches the gather from those, and only then runs full
wavefront 0 — so the collective hides under the communication-free
compute the fusion criterion guarantees.  The halo table is
double-buffered: the executor keeps two persistent scratch tables per
dtype and alternates them call to call, scattering each gather into the
idle buffer so wavefront 0 never waits on an in-flight gather from the
previous call.  Stale pad slots are harmless — every wf1 read multiplies
them by a zero value slot.  ``cost_model.shard_comm_model`` prices the
hidden bytes against the duplicate prologue compute.

Static shapes: per-shard tile counts differ, so the stacked arrays are
padded to the max tiles/rows per shard; padded slots reuse the schedule's
own conventions (row ``n_j`` — or ``rows_per_shard`` for the local output
blocks — scatter-dropped, col 0 / val 0 no-ops).

The builder requires a *uniform* wavefront-0 grid (``uniform_split=True``,
the dispatch default) — the same precondition as the Pallas kernels — so a
tile index is a D1 row-block index and the halo owner map is one
``searchsorted``.  Non-uniform schedules return ``None`` and the dispatch
falls back to single-device execution, as it does on a trivial mesh.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.formats import CSR, csr_content_digest
from . import cost_model, fused_ops
from .schedule import DeviceSchedule
from .scheduler import Schedule, balanced_contiguous_partition, \
    resolve_mesh_layout

#: Valid output-combine strategies (plus "auto" at the dispatch layer).
COMBINE_MODES = ("psum", "reduce_scatter")


def mesh_key(mesh) -> tuple | None:
    """Hashable cache-key component for a mesh: axis names + shape.

    ``None`` for ``mesh=None`` *and* for single-device meshes — a trivial
    mesh dispatches identically to no mesh, so the two must share cache
    entries."""
    if mesh is None:
        return None
    shape = tuple(int(s) for s in np.shape(mesh.devices))
    if int(np.prod(shape)) <= 1:
        return None
    return (tuple(str(n) for n in mesh.axis_names), shape)


@dataclasses.dataclass(frozen=True)
class ShardedSchedule:
    """Per-shard restructuring of a uniform ``DeviceSchedule``.

    All stacked arrays carry the shard dimension flattened into their
    leading axis (``S * per_shard``) so ``shard_map`` with ``P(axes)``
    hands each device exactly its block."""

    n_shards: int                 # row-block shards (the mesh's row axis)
    n_repl: int                   # column replicas (1 = pure 1-D layout)
    combine: str                  # "psum" | "reduce_scatter"
    n_depth: int                  # depth layers (1 = no 2.5D replication)
    overlap: bool                 # async halo gather under wf0 compute
    t_pad: int
    n_i: int
    n_j: int
    n_tiles0: int                 # global wavefront-0 tile count
    tiles_per_shard: int          # T0s (padded)
    tile_bounds: np.ndarray       # (S+1,) contiguous tile-index bounds
    tile_map: np.ndarray          # (S*T0s,) global tile id, pad = n_tiles0
    row_map: np.ndarray           # (S*T0s*t,) global padded D1 row, pad = 0
    # wavefront 0 (gathered from DeviceSchedule in shard order)
    j_rows0: np.ndarray           # (S*T0s, j0_max) global D rows, pad = n_j
    ell_cols0: np.ndarray         # (S*T0s, j0_max, w0) tile-local
    ell_vals0: np.ndarray
    # wavefront 1, stacked over G = S*Z groups (cols remapped to the
    # group's depth layer's halo-table positions)
    wf1_per_shard: int            # T1s (padded; 0 = empty wavefront)
    j_rows1: np.ndarray           # (G*T1s, j1_max) pad = n_j
    ell_cols1: np.ndarray         # (G*T1s, j1_max, w1) halo positions
    ell_vals1: np.ndarray
    spill_per_shard: int          # L (padded)
    spill_rows1: np.ndarray       # (G*L,) global D rows, pad = n_j
    spill_cols1: np.ndarray       # (G*L,) halo positions, pad = 0
    spill_vals1: np.ndarray       # (G*L,) pad = 0
    # halo exchange (per depth layer; Z = 1 is the flat single-table case)
    halo_rows: np.ndarray         # (H,) sorted global D1 rows wf1 reads
    halo_pad: int                 # Hp: padded per-layer halo-table height
    send_per_shard: int           # Hs (padded)
    send_local: np.ndarray        # (G*Hs,) shard-local padded row, pad = 0
    send_pos: np.ndarray          # (Z, S, Hs) layer-table position, pad=Hp
    # async-overlap composed indexing: wavefront-1 column/spill indices
    # remapped from layer-table POSITIONS to SLOTS of the raw all-gather
    # result (s * Hs + k), so the deferred exchange never materializes the
    # halo table at all — the gather's flat output is read directly
    ell_cols1_ov: np.ndarray      # (G*T1s, j1_max, w1) gather slots
    spill_cols1_ov: np.ndarray    # (G*L,) gather slots, pad = 0
    # output ownership (the reduce-scatter row remap): every D row is
    # owned by the one shard that writes it — wf0 fused rows by their
    # tile's shard, wf1 rows by their wf1 tile's shard
    rows_per_shard: int           # R: padded owned rows per shard
    out_perm: np.ndarray          # (n_j,) permuted block position of row j
    out_rows0: np.ndarray         # (S*T0s, j0_max) shard-local out, pad = R
    out_rows1: np.ndarray         # (S*T1s, j1_max) shard-local out, pad = R
    out_spill: np.ndarray         # (S*L,) shard-local out, pad = R
    #: ``cost_model.shard_comm_model`` of this partition (halo all-gather
    #: bytes vs full-D1 replication; psum vs reduce-scatter combine) —
    #: surfaced through the schedule entry's traffic model.
    comm_model: dict = dataclasses.field(default_factory=dict)

    @property
    def halo_size(self) -> int:
        return int(self.halo_rows.shape[0])

    @property
    def layout(self) -> str:
        """"1d" (row shards only), "1.5d" (column replicas too), or
        "2.5d" (depth layers as well)."""
        if self.n_depth > 1:
            return "2.5d"
        return "1d" if self.n_repl == 1 else "1.5d"

    def shard_tile_counts(self) -> np.ndarray:
        """Real (unpadded) wavefront-0 tiles per shard — the balance the
        Eq-3 partition produced, pinned by tests."""
        return np.diff(self.tile_bounds)

    def shard_owned_counts(self) -> np.ndarray:
        """Real (unpadded) owned output rows per shard — the row blocks of
        the reduce-scatter combine, disjoint and exhaustive over D."""
        pos = np.sort(self.out_perm)
        bounds = np.searchsorted(pos, np.arange(self.n_shards + 1)
                                 * self.rows_per_shard)
        return np.diff(bounds)


def _pad_gather(src: np.ndarray, idx: np.ndarray, pad_value) -> np.ndarray:
    """Gather ``src[idx]`` where ``idx == src.shape[0]`` selects a padding
    element filled with ``pad_value``."""
    pad = np.full((1,) + src.shape[1:], pad_value, dtype=src.dtype)
    return np.concatenate([src, pad], axis=0)[idx]


def _remap_to_halo(cols: np.ndarray, halo_rows: np.ndarray) -> np.ndarray:
    """Global D1 rows -> positions in the halo table; rows not in the halo
    (only possible for zero-valued slots, which the halo set filters) map
    to position 0 where the zero value makes the read a no-op."""
    if halo_rows.size == 0:
        return np.zeros_like(cols)
    pos = np.searchsorted(halo_rows, cols)
    pos = np.minimum(pos, halo_rows.size - 1)
    hit = halo_rows[pos] == cols
    return np.where(hit, pos, 0).astype(np.int32)


def _owner_of_tiles(bounds: np.ndarray, tile_ids: np.ndarray,
                    n_shards: int) -> np.ndarray:
    """Owning shard of each tile id under contiguous ``bounds``."""
    own = np.searchsorted(bounds, tile_ids, side="right") - 1
    return np.clip(own, 0, n_shards - 1)


def _pack_by_group(owners: np.ndarray, n_groups: int) -> tuple:
    """Pack items into equal-stride per-group slots — the one packing rule
    behind the halo send tables, the output-ownership permutation, and the
    spill-lane co-location.

    Returns ``(counts, stride, order, dst)``: item ``order[k]`` lands at
    flat slot ``dst[k] = group * stride + rank_within_group`` where
    ``stride = max(counts, 1)`` (so every group's block is padded to the
    same height) and ``order`` walks the items in stable group order."""
    owners = np.asarray(owners, dtype=np.int64)
    counts = np.bincount(owners, minlength=n_groups)
    stride = max(int(counts.max()) if owners.size else 0, 1)
    order = np.argsort(owners, kind="stable")
    offsets = np.concatenate([[0], np.cumsum(counts)])
    dst = (np.repeat(np.arange(n_groups, dtype=np.int64), counts) * stride
           + np.arange(owners.size, dtype=np.int64)
           - np.repeat(offsets[:-1], counts))
    return counts, stride, order, dst


def _local_out_rows(stacked_rows: np.ndarray, shard_of: np.ndarray,
                    pos_of_row: np.ndarray, n_j: int,
                    r_per: int) -> np.ndarray:
    """Shard-local output positions for a stacked global-row array: real
    rows map to ``pos_of_row - shard * R`` (in [0, R) — every row in a
    shard's stack is owned by that shard), pad slots map to ``R``
    (scatter-dropped)."""
    if stacked_rows.size == 0 or n_j == 0:
        return np.full(stacked_rows.shape, r_per, np.int32)
    real = stacked_rows < n_j
    safe = np.minimum(stacked_rows, max(n_j - 1, 0))
    loc = pos_of_row[safe] - shard_of.reshape(
        shard_of.shape + (1,) * (stacked_rows.ndim - shard_of.ndim)) * r_per
    return np.where(real, loc, r_per).astype(np.int32)


def build_sharded_schedule(a: CSR, sched: Schedule, dsched: DeviceSchedule,
                           mesh_shape, *, b_col: int, c_col: int,
                           b_is_sparse: bool,
                           width_cap: int | None = None,
                           layout: str = "1d",
                           combine: str = "auto",
                           dtype_bytes: int = 4,
                           overlap: bool | str = False):
    """Partition a uniform schedule over a mesh shape (an int or a shape
    tuple) under a layout — ``scheduler.resolve_mesh_layout`` is the one
    place the shape becomes (row shards × column replicas × depth layers).

    ``combine`` picks the output-combine strategy (``"auto"`` defers to
    ``shard_comm_model``'s byte pricing); ``overlap`` enables the async
    halo gather (``"auto"`` defers to the same model's hidden-bytes vs
    duplicate-compute pricing).  Returns ``None`` when the schedule is not
    a uniform wavefront-0 grid (the caller falls back to single-device
    dispatch)."""
    if combine not in COMBINE_MODES + ("auto",):
        raise ValueError(f"combine={combine!r}; expected one of "
                         f"{COMBINE_MODES + ('auto',)}")
    if not isinstance(overlap, (bool, np.bool_)) and overlap != "auto":
        raise ValueError(f"overlap={overlap!r}; expected a bool or 'auto'")
    s_n, n_repl, n_depth = resolve_mesh_layout(mesh_shape, layout)
    if s_n * n_repl * n_depth <= 1 or not fused_ops._is_uniform(dsched):
        return None
    n_groups = s_n * n_depth       # wf1 work groups: row shard × depth
    t = dsched.t_pad
    n_t = dsched.n_tiles0
    n_j = dsched.n_j
    wf0, wf1 = sched.wavefronts

    # ---- wavefront 0: Eq-3-balanced contiguous tile partition over the
    # mesh's row axis (replica groups share tiles) ----
    costs0 = cost_model.tile_costs_batch(
        a, [tl.i_start for tl in wf0], [tl.i_end for tl in wf0],
        [tl.j_rows for tl in wf0], b_col, c_col, b_is_sparse,
        width_cap=width_cap)
    tile_bounds = balanced_contiguous_partition(costs0, s_n)
    per = np.diff(tile_bounds)
    t0s = max(int(per.max()) if per.size else 0, 1)
    tile_map = np.full((s_n, t0s), n_t, dtype=np.int64)
    for s in range(s_n):
        ids = np.arange(tile_bounds[s], tile_bounds[s + 1], dtype=np.int64)
        tile_map[s, : ids.size] = ids
    tile_map = tile_map.reshape(-1)

    j_rows0 = _pad_gather(dsched.j_rows0, tile_map, n_j)
    ell_cols0 = _pad_gather(dsched.ell_cols0, tile_map, 0)
    ell_vals0 = _pad_gather(dsched.ell_vals0, tile_map, 0)

    valid = tile_map < n_t
    row_map = (np.where(valid, tile_map, 0)[:, None] * t
               + np.arange(t, dtype=np.int64)[None, :])
    row_map = np.where(valid[:, None], row_map, 0).reshape(-1)

    # ---- wavefront 1: cost-balanced tile partition over S*Z groups
    # (group g = shard * Z + layer; Z = 1 reduces to the per-shard split).
    halo_rows = dsched.wf1_dep_rows()
    h = int(halo_rows.shape[0])
    row_bounds = tile_bounds * t
    n_t1 = dsched.n_tiles1
    if n_t1:
        costs1 = cost_model.tile_costs_batch(
            a, np.zeros(n_t1, np.int64), np.zeros(n_t1, np.int64),
            [tl.j_rows for tl in wf1], b_col, c_col, b_is_sparse,
            width_cap=width_cap)
        bounds1 = balanced_contiguous_partition(costs1, n_groups)
        per1 = np.diff(bounds1)
        t1s = max(int(per1.max()), 1)
        tmap1 = np.full((n_groups, t1s), n_t1, dtype=np.int64)
        for g in range(n_groups):
            ids = np.arange(bounds1[g], bounds1[g + 1], dtype=np.int64)
            tmap1[g, : ids.size] = ids
        tmap1 = tmap1.reshape(-1)
        j_rows1 = _pad_gather(dsched.j_rows1, tmap1, n_j)
        cols1_g = _pad_gather(dsched.ell_cols1, tmap1, 0)    # global rows
        vals1 = _pad_gather(dsched.ell_vals1, tmap1, 0)
        grp_of_t1 = _owner_of_tiles(bounds1, np.arange(n_t1, dtype=np.int64),
                                    n_groups)
    else:
        bounds1 = np.zeros(n_groups + 1, dtype=np.int64)
        t1s = 0
        j_rows1 = np.full((0, 1), n_j, dtype=np.int32)
        cols1_g = np.zeros((0, 1, 1), dtype=np.int32)
        vals1 = np.zeros((0, 1, 1), dtype=np.float32)
        grp_of_t1 = np.zeros(0, dtype=np.int64)

    # ---- output ownership: row -> owning shard -> permuted position ----
    # Every D row is written by exactly one tile (Schedule.validate), so
    # the per-shard write sets are disjoint and exhaustive: wf0 fused rows
    # belong to their tile's shard, wf1 rows to their wf1 tile's shard
    # (= its group's row shard).  ``grp_row`` additionally remembers the
    # full (shard, layer) group for wf1 rows, which co-locates spill lanes
    # and assigns halo deps to depth layers; wf0 rows sit at layer 0.
    own_row = np.zeros(max(n_j, 1), dtype=np.int64)
    sizes0 = np.asarray([tl.n_j for tl in wf0], dtype=np.int64)
    if sizes0.sum():
        j0_all = np.concatenate([tl.j_rows for tl in wf0]).astype(np.int64)
        t0_of = np.repeat(np.arange(len(wf0), dtype=np.int64), sizes0)
        own_row[j0_all] = _owner_of_tiles(tile_bounds, t0_of, s_n)
    grp_row = own_row * n_depth
    if n_t1:
        sizes1 = np.asarray([tl.n_j for tl in wf1], dtype=np.int64)
        j1_all = np.concatenate([tl.j_rows for tl in wf1]).astype(np.int64)
        t1_of = np.repeat(np.arange(n_t1, dtype=np.int64), sizes1)
        own_row[j1_all] = grp_of_t1[t1_of] // n_depth
        grp_row[j1_all] = grp_of_t1[t1_of]
    own_row = own_row[:n_j]
    grp_row = grp_row[: max(n_j, 1)]
    _, r_per, o_ord, o_dst = _pack_by_group(own_row, s_n)
    pos_of_row = np.empty(n_j, dtype=np.int64)
    pos_of_row[o_ord] = o_dst

    # ---- spill-lane grouping (needed before the halo tables: a spill's
    # halo dep must live in its depth layer's table) ----
    n_sp = int(dsched.spill_rows1.shape[0])
    if n_sp:
        sp_grp = grp_row[dsched.spill_rows1.astype(np.int64)]
    else:
        sp_grp = np.zeros(0, dtype=np.int64)

    # ---- halo: per-depth-layer dependency tables + send schedules ----
    # Layer z's table H_z is the union of its groups' wf1 deps; Z = 1
    # makes H_0 exactly ``wf1_dep_rows()`` (the flat single-table case).
    if n_depth > 1:
        layer_of_t1 = grp_of_t1 % n_depth
        halo_layers_list = []
        for z in range(n_depth):
            parts = []
            if n_t1:
                tz = np.where(layer_of_t1 == z)[0]
                if tz.size:
                    cz = dsched.ell_cols1[tz][dsched.ell_vals1[tz] != 0]
                    parts.append(cz.ravel().astype(np.int64))
            if n_sp:
                m = (sp_grp % n_depth == z) & (dsched.spill_vals1 != 0)
                parts.append(dsched.spill_cols1[m].astype(np.int64))
            hz = (np.unique(np.concatenate(parts)) if parts
                  else np.zeros(0, dtype=np.int64))
            halo_layers_list.append(hz)
    else:
        halo_layers_list = [halo_rows.astype(np.int64)]
    h_pad = max(max((hz.size for hz in halo_layers_list), default=0), 1)
    cnt = np.zeros((s_n, n_depth), dtype=np.int64)
    own_z = []
    for z, hz in enumerate(halo_layers_list):
        if hz.size:
            oz = np.clip(np.searchsorted(row_bounds, hz, side="right") - 1,
                         0, s_n - 1)
        else:
            oz = np.zeros(0, dtype=np.int64)
        own_z.append(oz)
        cnt[:, z] = np.bincount(oz, minlength=s_n)
    hs = max(int(cnt.max()), 1)
    send_local = np.zeros(n_groups * hs, dtype=np.int32)
    send_pos = np.full((n_depth, s_n, hs), h_pad, dtype=np.int32)
    for z, hz in enumerate(halo_layers_list):
        if not hz.size:
            continue
        oz = own_z[z]
        # hz is sorted and ownership is contiguous, so the stable group
        # order is the identity: slot = rank within the shard's run
        offs = np.concatenate([[0], np.cumsum(cnt[:, z])])
        rank = np.arange(hz.size, dtype=np.int64) - offs[oz]
        g = oz * n_depth + z
        send_local[g * hs + rank] = (hz - row_bounds[oz]).astype(np.int32)
        send_pos[z, oz, rank] = np.arange(hz.size, dtype=np.int32)
    if h == 0:
        send_pos = np.zeros((n_depth, s_n, hs), dtype=np.int32)

    # overlap slot composition: per layer, table position p lives at slot
    # (s * hs + k) of the raw all-gather output — composing wf1's position
    # indices with that map at build time lets the async path skip the
    # per-call table scatter entirely (pad positions fold to slot 0, whose
    # junk value is killed by the matching zero pad values)
    slot_of = np.zeros((n_depth, h_pad + 1), dtype=np.int32)
    for z in range(n_depth):
        pz = send_pos[z]                        # (S, Hs) positions
        valid_p = pz < h_pad
        slot = (np.arange(s_n, dtype=np.int32)[:, None] * hs
                + np.arange(hs, dtype=np.int32)[None, :])
        slot_of[z][pz[valid_p]] = slot[valid_p]

    # ---- wavefront-1 halo remap: each group's cols against its layer ----
    if n_depth > 1 and n_t1:
        cols1 = np.zeros_like(cols1_g, dtype=np.int32)
        layer_of_stack = (np.repeat(np.arange(n_groups, dtype=np.int64),
                                    t1s) % n_depth)
        for z in range(n_depth):
            m = layer_of_stack == z
            if m.any():
                cols1[m] = _remap_to_halo(cols1_g[m], halo_layers_list[z])
    else:
        cols1 = _remap_to_halo(cols1_g, halo_layers_list[0]) if n_t1 \
            else cols1_g

    shard_of0 = np.repeat(np.arange(s_n, dtype=np.int64), t0s)
    out_rows0 = _local_out_rows(j_rows0, shard_of0, pos_of_row, n_j, r_per)
    if t1s:
        shard_of1 = np.repeat(np.arange(n_groups, dtype=np.int64)
                              // n_depth, t1s)
        out_rows1 = _local_out_rows(j_rows1, shard_of1, pos_of_row, n_j,
                                    r_per)
    else:
        out_rows1 = np.full(j_rows1.shape, r_per, dtype=np.int32)

    # ---- spill lanes: co-located with their target row's owning group
    # (the group whose wf1 tile wrote the body, so the reduce-scatter
    # partials stay owner-disjoint and the body .set precedes the .add,
    # and the spill's halo dep is in the same layer's table) ----
    if n_sp:
        if n_depth > 1:
            sp_remap = np.zeros(n_sp, dtype=np.int32)
            for z in range(n_depth):
                m = sp_grp % n_depth == z
                if m.any():
                    sp_remap[m] = _remap_to_halo(
                        dsched.spill_cols1[m], halo_layers_list[z])
        else:
            sp_remap = _remap_to_halo(dsched.spill_cols1,
                                      halo_layers_list[0])
        _, sp_l, sp_order, dst = _pack_by_group(sp_grp, n_groups)
        spill_rows = np.full(n_groups * sp_l, n_j, np.int32)
        spill_cols = np.zeros(n_groups * sp_l, np.int32)
        spill_vals = np.zeros(n_groups * sp_l, np.float32)
        spill_rows[dst] = dsched.spill_rows1[sp_order]
        spill_cols[dst] = sp_remap[sp_order]
        spill_vals[dst] = dsched.spill_vals1[sp_order]
        out_spill = np.full(n_groups * sp_l, r_per, np.int32)
        out_spill[dst] = (pos_of_row[dsched.spill_rows1[sp_order].astype(
            np.int64)] - (sp_grp[sp_order] // n_depth) * r_per).astype(
            np.int32)
    else:
        sp_l = 0
        spill_rows = np.zeros(0, np.int32)
        spill_cols = np.zeros(0, np.int32)
        spill_vals = np.zeros(0, np.float32)
        out_spill = np.zeros(0, np.int32)

    # wf1 position indices composed through each group's layer slot map
    # (the overlap executor's direct-from-gather read)
    if t1s:
        layer1 = (np.repeat(np.arange(n_groups, dtype=np.int64), t1s)
                  % n_depth)
        cols1_ov = slot_of[layer1[:, None, None],
                           cols1.astype(np.int64)].astype(np.int32)
    else:
        cols1_ov = cols1
    if sp_l:
        layer_sp = (np.repeat(np.arange(n_groups, dtype=np.int64), sp_l)
                    % n_depth)
        spill_cols_ov = slot_of[layer_sp,
                                spill_cols.astype(np.int64)].astype(np.int32)
    else:
        spill_cols_ov = spill_cols

    wf0_bytes = float(costs0.sum()) * dtype_bytes
    comm = cost_model.shard_comm_model(s_n, h, dsched.n_i, c_col,
                                       n_j=n_j, n_repl=n_repl,
                                       combine_rows=s_n * r_per,
                                       dtype_bytes=dtype_bytes,
                                       n_depth=n_depth, overlap=overlap,
                                       wf0_bytes=wf0_bytes)
    mode = comm["combine"] if combine == "auto" else combine
    overlap_on = bool(comm["overlap"]) and h > 0
    return ShardedSchedule(
        n_shards=s_n, n_repl=n_repl, combine=mode,
        n_depth=n_depth, overlap=overlap_on,
        t_pad=t, n_i=dsched.n_i, n_j=n_j, n_tiles0=n_t,
        tiles_per_shard=t0s, tile_bounds=tile_bounds, tile_map=tile_map,
        row_map=row_map,
        j_rows0=j_rows0, ell_cols0=ell_cols0, ell_vals0=ell_vals0,
        wf1_per_shard=t1s, j_rows1=j_rows1, ell_cols1=cols1,
        ell_vals1=vals1,
        spill_per_shard=sp_l, spill_rows1=spill_rows,
        spill_cols1=spill_cols, spill_vals1=spill_vals,
        halo_rows=halo_rows, halo_pad=h_pad, send_per_shard=hs,
        send_local=send_local.reshape(-1), send_pos=send_pos,
        ell_cols1_ov=cols1_ov, spill_cols1_ov=spill_cols_ov,
        rows_per_shard=r_per, out_perm=pos_of_row,
        out_rows0=out_rows0, out_rows1=out_rows1, out_spill=out_spill,
        comm_model=comm,
    )


# --------------------------------------------------------------------------
# Executors
# --------------------------------------------------------------------------
def _shard_executor(shard: ShardedSchedule, mesh, kind: str):
    """Build (and memoize per (mesh, kind)) the jitted shard_map executor.

    The schedule's index arrays are closed over as constants — they are
    part of the (cached) schedule, so jit's tracing cache stays hot across
    calls with the same operand shapes."""
    memo = getattr(shard, "_exec_memo", None)
    if memo is None:
        memo = {}
        object.__setattr__(shard, "_exec_memo", memo)
    key = (mesh, kind)
    fn = memo.get(key)
    if fn is not None:
        return fn

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ...models.sharding import mesh_row_repl_axes, shard_map

    row_axes, repl_axes, depth_axes = mesh_row_repl_axes(mesh, shard.layout)
    mesh_sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
    if (int(np.prod([mesh_sizes[ax] for ax in row_axes])) != shard.n_shards
            or int(np.prod([mesh_sizes[ax] for ax in repl_axes] or [1]))
            != shard.n_repl
            or int(np.prod([mesh_sizes[ax] for ax in depth_axes] or [1]))
            != shard.n_depth):
        raise ValueError(
            f"mesh shape {dict(mesh_sizes)} does not match the schedule's "
            f"{shard.n_shards}x{shard.n_repl}x{shard.n_depth} "
            f"({shard.layout}) partition")
    sh = P(row_axes)        # leading dim carries the row-shard axis
    # wavefront-1 stacks carry the S*Z group dimension: row axes are the
    # slow index, depth axes the fast one (group g = shard * Z + layer)
    sh1 = P(tuple(row_axes) + tuple(depth_axes)) if depth_axes else sh
    rep = P(None, repl_axes) if repl_axes else P()       # column replicas
    sh_col = P(row_axes, repl_axes) if repl_axes else P(row_axes)
    reduce_scatter = shard.combine == "reduce_scatter"
    overlap = bool(shard.overlap)
    t, t0s = shard.t_pad, shard.tiles_per_shard
    t1s, sp_l = shard.wf1_per_shard, shard.spill_per_shard
    n_j, h, hp = shard.n_j, shard.halo_size, shard.halo_pad
    r_per = shard.rows_per_shard
    # local output-buffer height and scatter targets per combine mode: the
    # psum arm scatters global D rows into a full (n_j, cc) partial and
    # all-reduces; the reduce-scatter arm scatters shard-local owned
    # positions into the shard's own (R, cc) block and emits it directly
    out_n = r_per if reduce_scatter else n_j
    rows0_np = shard.out_rows0 if reduce_scatter else shard.j_rows0
    rows1_np = shard.out_rows1 if reduce_scatter else shard.j_rows1
    srows_np = shard.out_spill if reduce_scatter else shard.spill_rows1
    # index arrays are dtype-independent: convert (and upload) once at
    # build time, not per call — only the value arrays depend on the
    # operands' dtype and get their own tiny per-dtype memo below
    send_pos = jnp.asarray(shard.send_pos)   # (Z, S, Hs) replicated const
    # the overlap executor reads the raw all-gather output through
    # build-time composed slot indices (no halo-table materialization),
    # so its wf1/spill index stacks are the _ov variants
    async_halo = overlap and h > 0
    cols1_np = shard.ell_cols1_ov if async_halo else shard.ell_cols1
    scols_np = shard.spill_cols1_ov if async_halo else shard.spill_cols1
    idx_args = (jnp.asarray(rows0_np), jnp.asarray(shard.ell_cols0),
                jnp.asarray(rows1_np), jnp.asarray(cols1_np),
                jnp.asarray(srows_np), jnp.asarray(scols_np),
                jnp.asarray(shard.send_local))
    vals_by_dtype: dict = {}

    def _depth_index():
        """This device's depth-layer index (C-order over the depth axes —
        the same folding ``resolve_mesh_layout`` applied)."""
        idx = None
        for ax in depth_axes:
            i = jax.lax.axis_index(ax)
            idx = i if idx is None else idx * mesh_sizes[ax] + i
        return idx

    def _layer_pos():
        """The scatter positions of this device's depth layer's halo
        table, flattened over the row axis: (S*Hs,)."""
        if not depth_axes:
            return send_pos[0].reshape(-1)
        zi = _depth_index()
        return jax.lax.dynamic_index_in_dim(
            send_pos, zi, keepdims=False).reshape(-1)

    def _halo_table(contrib, dtype):
        """Leaf stage of the staged exchange (synchronous arm): all-gather
        this fiber's send rows over the row axis and scatter them into the
        layer's table at the schedule's positions."""
        cc = contrib.shape[-1]
        gathered = jax.lax.all_gather(contrib, row_axes)   # (S, Hs, cc)
        flat = gathered.reshape(-1, cc)
        base = jnp.zeros((hp, cc), dtype)
        return base.at[_layer_pos()].set(flat, mode="drop")

    def _mask_wf0(d):
        """Only depth layer 0 emits the (replicated) wavefront-0 rows —
        the depth combine would otherwise multiply them by Z."""
        if not depth_axes:
            return d
        return jnp.where(_depth_index() == 0, d, jnp.zeros_like(d))

    def _combine(d):
        """Root stage: psum partials over the depth axes, then the output
        combine — psum over the row axis, or (owner-disjoint partials)
        emit the shard's own block."""
        if reduce_scatter:
            if depth_axes:
                d = jax.lax.psum(d, tuple(depth_axes))
            return d
        return jax.lax.psum(d, tuple(row_axes) + tuple(depth_axes))

    def wf1_apply(d, halo, rows1_s, cols1_s, vals1_s,
                  srows_s, scols_s, svals_s):
        """This group's wavefront-1 share off an assembled halo table."""
        c_col = d.shape[1]
        if t1s:
            rows1 = fused_ops._ell_rows(cols1_s, vals1_s, halo)
            d = d.at[rows1_s.reshape(-1)].set(
                rows1.reshape(-1, c_col), mode="drop")
        if sp_l:
            d = d.at[srows_s].add(
                svals_s.astype(d.dtype)[:, None] * halo[scols_s])
        return d

    def _finish_body(d1_flat, c, halo, rows0_s, cols0_s, vals0_s, rows1_s,
                     cols1_s, vals1_s, srows_s, scols_s, svals_s,
                     send_local_s):
        """wf0 scatter (+ sync halo when no prologue ran), wf1, combine."""
        c_col = c.shape[1]
        d1_t = d1_flat.reshape(t0s, t, c_col)
        rows0 = jax.vmap(fused_ops._ell_rows)(cols0_s, vals0_s, d1_t)
        d = jnp.zeros((out_n, c_col), c.dtype).at[
            rows0_s.reshape(-1)].set(rows0.reshape(-1, c_col),
                                     mode="drop")
        d = _mask_wf0(d)
        if h and halo is None:
            halo = _halo_table(d1_flat[send_local_s], c.dtype)
        if h:
            d = wf1_apply(d, halo, rows1_s, cols1_s, vals1_s,
                          srows_s, scols_s, svals_s)
        return _combine(d)

    def _issue_gather(d1_flat, send_local_s):
        """Async exchange: slice this group's send rows out of D1 and
        issue the all-gather BEFORE the wavefront-0 scatter stage below —
        the collective hides under the communication-free compute the
        fusion criterion guarantees.  The raw gather output (S*Hs slots)
        is returned as-is; wavefront 1 reads it through build-time
        composed slot indices, so the deferred exchange never pays the
        per-call halo-table scatter the eager path does."""
        contrib = d1_flat[send_local_s]                    # (Hs, c_col)
        gathered = jax.lax.all_gather(contrib, row_axes)   # (S, Hs, cc)
        return gathered.reshape(-1, contrib.shape[-1])

    def per_shard_gemm(b_blk, c, rows0_s, cols0_s, vals0_s, rows1_s,
                       cols1_s, vals1_s, srows_s, scols_s, svals_s,
                       send_local_s):
        d1_flat = b_blk @ c                                # (T0s*t, c_col)
        halo = _issue_gather(d1_flat, send_local_s) if async_halo else None
        out = _finish_body(d1_flat, c, halo, rows0_s, cols0_s, vals0_s,
                           rows1_s, cols1_s, vals1_s, srows_s, scols_s,
                           svals_s, send_local_s)
        return (out, halo) if async_halo else out

    def per_shard_spmm(o_cols_s, o_vals_s, d1_spill_s, c, rows0_s,
                       cols0_s, vals0_s, rows1_s, cols1_s, vals1_s,
                       srows_s, scols_s, svals_s, send_local_s):
        o_cols_flat = o_cols_s.reshape(t0s * t, -1)
        o_vals_flat = o_vals_s.reshape(t0s * t, -1)
        # op-1 SpMM per tile: hybrid ELL body over replicated C + the
        # tile's pre-accumulated spill delta
        d1_flat = fused_ops._ell_rows(o_cols_flat, o_vals_flat, c) \
            + d1_spill_s
        halo = _issue_gather(d1_flat, send_local_s) if async_halo else None
        out = _finish_body(d1_flat, c, halo, rows0_s, cols0_s, vals0_s,
                           rows1_s, cols1_s, vals1_s, srows_s, scols_s,
                           svals_s, send_local_s)
        return (out, halo) if async_halo else out

    if kind == "gemm":
        body = per_shard_gemm
        lead_specs = (sh, rep)
    else:
        body = per_shard_spmm
        lead_specs = (sh, sh, sh_col, rep)
    # operand specs: leading op inputs, then the schedule's stacked index
    # arrays — wf0 stacks shard over the row axis, wf1/spill/send stacks
    # over the row × depth group axes
    in_specs = lead_specs + (sh, sh, sh) + (sh1,) * 7
    out_specs = sh_col if reduce_scatter else rep
    if async_halo:
        # the raw gather output rides along as a second result: depth
        # layers own their slice, column replicas their columns,
        # replicated over the row axis (it IS an all-gather result)
        flat_spec = P(tuple(depth_axes) or None,
                      tuple(repl_axes) or None)
        out_specs = (out_specs, flat_spec)
    mapped = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs,
                       check_vma=not async_halo)
    fn = jax.jit(mapped)
    halo_bufs: dict = {}

    def run(*operands):
        dtype = operands[-1].dtype                  # C is the last operand
        vals = vals_by_dtype.get(dtype)
        if vals is None:
            vals = (jnp.asarray(shard.ell_vals0, dtype),
                    jnp.asarray(shard.ell_vals1, dtype),
                    jnp.asarray(shard.spill_vals1, dtype))
            vals_by_dtype[dtype] = vals
        rows0, cols0, rows1_a, cols1_a, srows, scols, send_local = \
            idx_args
        args = operands + (rows0, cols0, vals[0], rows1_a, cols1_a,
                           vals[1], srows, scols, vals[2], send_local)
        if not async_halo:
            return fn(*args)
        # double buffering: keep the last TWO gather outputs alive so the
        # next call's in-flight exchange never reuses a buffer a still-
        # running wavefront-1 consumer may be reading
        out, flat = fn(*args)
        bufs = halo_bufs.setdefault(dtype, [None, None, 0])
        idle = bufs[2]
        bufs[idle] = flat
        bufs[2] = idle ^ 1
        return out

    memo[key] = run
    return run


def _device_const(shard: ShardedSchedule, attr: str):
    """A ShardedSchedule index array as a device array, uploaded once per
    schedule (memoized on the frozen instance)."""
    import jax.numpy as jnp
    cache_attr = f"_{attr}_jax"
    arr = getattr(shard, cache_attr, None)
    if arr is None:
        arr = jnp.asarray(getattr(shard, attr))
        object.__setattr__(shard, cache_attr, arr)
    return arr


def _pad_cols(c, n_repl: int):
    """Pad C's trailing dim to a multiple of ``n_repl`` so the replica
    axis splits it evenly; callers slice the padding back off the output."""
    import jax.numpy as jnp
    cc = int(c.shape[1])
    cc_pad = -(-cc // n_repl) * n_repl
    if cc_pad != cc:
        c = jnp.pad(c, ((0, 0), (0, cc_pad - cc)))
    return c, cc


def _finish(shard: ShardedSchedule, out, c_col: int):
    """Post-executor output assembly: the reduce-scatter arm's permuted
    owner blocks are mapped back to D's row order (one gather — each
    owned block crosses the wire once, the byte count
    ``combine_bytes_reduce_scatter`` prices), and column padding from the
    replica split is sliced off."""
    if shard.combine == "reduce_scatter":
        out = out[_device_const(shard, "out_perm")]
    if int(out.shape[1]) != c_col:
        out = out[:, :c_col]
    return out


def sharded_gemm_spmm(shard: ShardedSchedule, mesh, b, c):
    """GeMM-SpMM over the mesh: B row-blocks follow the tile partition."""
    import jax.numpy as jnp
    b = jnp.asarray(b)
    if b.shape[0] != shard.n_i:
        raise ValueError(f"b has {b.shape[0]} rows, schedule expects "
                         f"{shard.n_i}")
    c, c_col = _pad_cols(jnp.asarray(c), shard.n_repl)
    n_pad = shard.n_tiles0 * shard.t_pad
    b_pad = jnp.pad(b, ((0, n_pad - b.shape[0]), (0, 0)))
    b_blk = b_pad[_device_const(shard, "row_map")]    # (S*T0s*t, b_col)
    run = _shard_executor(shard, mesh, "gemm")
    return _finish(shard, run(b_blk, c), c_col)


def _op1_sharded(shard: ShardedSchedule, dsched: DeviceSchedule, a1: CSR,
                 dtype):
    """Shard-ordered op-1 hybrid pack as *device* arrays, memoized per
    (a1 content, cap, dtype) like ``fused_ops._op1_ell`` itself — the
    O(nnz) repack *and* the host-to-device upload happen once per
    schedule, not once per call (the op-1 arrays are the largest operands
    in the problem)."""
    import jax.numpy as jnp
    cap = dsched.width_cap
    memo_key = (csr_content_digest(a1),
                None if cap is None else int(cap), str(dtype))
    memo = getattr(shard, "_op1_memo", None)
    if memo is not None and memo[0] == memo_key:
        return memo[1]
    o_cols, o_vals, spill_flat, spill_cols, spill_vals = fused_ops._op1_ell(
        a1, dsched, width_cap=cap)
    # per-tile arrays -> shard order (pad tiles are zero ELL, a no-op)
    o_cols_s = _pad_gather(o_cols, shard.tile_map, 0)
    o_vals_s = _pad_gather(o_vals, shard.tile_map, 0)
    packed = (jnp.asarray(o_cols_s), jnp.asarray(o_vals_s, dtype),
              int(spill_flat.size), jnp.asarray(spill_flat),
              jnp.asarray(spill_cols), jnp.asarray(spill_vals, dtype))
    object.__setattr__(shard, "_op1_memo", (memo_key, packed))
    return packed


def sharded_spmm_spmm(shard: ShardedSchedule, dsched: DeviceSchedule,
                      mesh, a1: CSR, c):
    """SpMM-SpMM over the mesh: per-shard op-1 hybrid ELL against a
    replicated C; the op-1 spill delta is scattered globally then gathered
    into shard order with the same row map as the GeMM path's B blocks."""
    import jax.numpy as jnp
    c = jnp.asarray(c)
    if a1.n_rows != shard.n_i:
        raise ValueError(f"op-1 has {a1.n_rows} rows, schedule expects "
                         f"{shard.n_i}")
    if c.shape[0] != a1.n_cols:
        raise ValueError(f"c has {c.shape[0]} rows, op-1 has {a1.n_cols} "
                         f"columns")
    c, c_col = _pad_cols(c, shard.n_repl)
    cc_pad = c.shape[1]
    o_cols_s, o_vals_s, n_spill, spill_flat, spill_cols, spill_vals = \
        _op1_sharded(shard, dsched, a1, c.dtype)
    n_pad = shard.n_tiles0 * shard.t_pad
    d1_spill = jnp.zeros((n_pad, cc_pad), c.dtype)
    if n_spill:
        d1_spill = d1_spill.at[spill_flat].add(
            spill_vals.astype(c.dtype)[:, None] * c[spill_cols])
    d1_spill_blk = d1_spill[_device_const(shard, "row_map")]
    run = _shard_executor(shard, mesh, "spmm")
    return _finish(shard, run(o_cols_s, o_vals_s, d1_spill_blk, c), c_col)
