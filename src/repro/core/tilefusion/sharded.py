"""Sharded tile-fusion executors — the wavefront-0 tile grid over a mesh.

The paper balances locality against "sufficient workload for cores" on one
shared-memory node; this module lifts the same tradeoff to a device mesh.
The unit of distribution is the inspector's *fused schedule* (keeping the
fused tile intact is what makes wavefront 0 communication-free): the
wavefront-0 tile grid is partitioned 1-D row-block over the mesh's flattened
device axis, with contiguous tile groups balanced by their Eq-3 cost
(``scheduler.balanced_contiguous_partition``) so every shard streams
comparable fused-tile bytes.

Execution model (per shard, under the ``models/sharding.py`` shard_map
shim):

  wavefront 0   each shard computes the D1 rows of its own tiles (GeMM or
                hybrid-ELL op-1 SpMM) and its fused second-op rows — zero
                communication, by the fusion criterion every dependency is
                tile-local and therefore shard-local.
  halo          each shard contributes the wavefront-1 dependency rows
                (``DeviceSchedule.wf1_dep_rows``) it owns, one
                ``all_gather`` assembles the halo table on every device
                (``cost_model.shard_comm_model`` prices this against
                full-D1 replication).
  wavefront 1   wavefront-1 tiles and spill lanes are themselves
                partitioned over shards (cost-balanced), reading the halo
                table; the per-shard partial D outputs cover disjoint rows
                and one ``psum`` combines them.  That full-(n_j, c_col)
                all-reduce is the second (and for small halos the
                dominant) communication term — priced honestly as
                ``combine_bytes`` in the comm model; replacing it with a
                row-remapped reduce-scatter is the ROADMAP follow-on.

Static shapes: per-shard tile counts differ, so the stacked arrays are
padded to the max tiles/rows per shard; padded slots reuse the schedule's
own conventions (row ``n_j`` scatter-dropped, col 0 / val 0 no-ops).

The builder requires a *uniform* wavefront-0 grid (``uniform_split=True``,
the dispatch default) — the same precondition as the Pallas kernels — so a
tile index is a D1 row-block index and the halo owner map is one
``searchsorted``.  Non-uniform schedules return ``None`` and the dispatch
falls back to single-device execution, as it does on a trivial mesh.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.formats import CSR, csr_content_digest
from . import cost_model, fused_ops
from .schedule import DeviceSchedule
from .scheduler import Schedule, balanced_contiguous_partition


def mesh_key(mesh) -> tuple | None:
    """Hashable cache-key component for a mesh: axis names + shape.

    ``None`` for ``mesh=None`` *and* for single-device meshes — a trivial
    mesh dispatches identically to no mesh, so the two must share cache
    entries."""
    if mesh is None:
        return None
    shape = tuple(int(s) for s in np.shape(mesh.devices))
    if int(np.prod(shape)) <= 1:
        return None
    return (tuple(str(n) for n in mesh.axis_names), shape)


@dataclasses.dataclass(frozen=True)
class ShardedSchedule:
    """Per-shard restructuring of a uniform ``DeviceSchedule``.

    All stacked arrays carry the shard dimension flattened into their
    leading axis (``S * per_shard``) so ``shard_map`` with ``P(axes)``
    hands each device exactly its block."""

    n_shards: int
    t_pad: int
    n_i: int
    n_j: int
    n_tiles0: int                 # global wavefront-0 tile count
    tiles_per_shard: int          # T0s (padded)
    tile_bounds: np.ndarray       # (S+1,) contiguous tile-index bounds
    tile_map: np.ndarray          # (S*T0s,) global tile id, pad = n_tiles0
    row_map: np.ndarray           # (S*T0s*t,) global padded D1 row, pad = 0
    # wavefront 0 (gathered from DeviceSchedule in shard order)
    j_rows0: np.ndarray           # (S*T0s, j0_max) global D rows, pad = n_j
    ell_cols0: np.ndarray         # (S*T0s, j0_max, w0) tile-local
    ell_vals0: np.ndarray
    # wavefront 1 (cols remapped to halo-table positions)
    wf1_per_shard: int            # T1s (padded; 0 = empty wavefront)
    j_rows1: np.ndarray           # (S*T1s, j1_max) pad = n_j
    ell_cols1: np.ndarray         # (S*T1s, j1_max, w1) halo positions
    ell_vals1: np.ndarray
    spill_per_shard: int          # L (padded)
    spill_rows1: np.ndarray       # (S*L,) global D rows, pad = n_j
    spill_cols1: np.ndarray       # (S*L,) halo positions, pad = 0
    spill_vals1: np.ndarray       # (S*L,) pad = 0
    # halo exchange
    halo_rows: np.ndarray         # (H,) sorted global D1 rows wf1 reads
    send_per_shard: int           # Hs (padded)
    send_local: np.ndarray        # (S*Hs,) shard-local padded row, pad = 0
    send_pos: np.ndarray          # (S, Hs) halo-table position, pad = H
    #: ``cost_model.shard_comm_model`` of this partition (halo all-gather
    #: bytes vs full-D1 replication) — surfaced through the schedule
    #: entry's traffic model.
    comm_model: dict = dataclasses.field(default_factory=dict)

    @property
    def halo_size(self) -> int:
        return int(self.halo_rows.shape[0])

    def shard_tile_counts(self) -> np.ndarray:
        """Real (unpadded) wavefront-0 tiles per shard — the balance the
        Eq-3 partition produced, pinned by tests."""
        return np.diff(self.tile_bounds)


def _pad_gather(src: np.ndarray, idx: np.ndarray, pad_value) -> np.ndarray:
    """Gather ``src[idx]`` where ``idx == src.shape[0]`` selects a padding
    element filled with ``pad_value``."""
    pad = np.full((1,) + src.shape[1:], pad_value, dtype=src.dtype)
    return np.concatenate([src, pad], axis=0)[idx]


def _remap_to_halo(cols: np.ndarray, halo_rows: np.ndarray) -> np.ndarray:
    """Global D1 rows -> positions in the halo table; rows not in the halo
    (only possible for zero-valued slots, which the halo set filters) map
    to position 0 where the zero value makes the read a no-op."""
    if halo_rows.size == 0:
        return np.zeros_like(cols)
    pos = np.searchsorted(halo_rows, cols)
    pos = np.minimum(pos, halo_rows.size - 1)
    hit = halo_rows[pos] == cols
    return np.where(hit, pos, 0).astype(np.int32)


def build_sharded_schedule(a: CSR, sched: Schedule, dsched: DeviceSchedule,
                           n_shards: int, *, b_col: int, c_col: int,
                           b_is_sparse: bool,
                           width_cap: int | None = None):
    """Partition a uniform schedule over ``n_shards`` devices.

    Returns ``None`` when the schedule is not a uniform wavefront-0 grid
    (the caller falls back to single-device dispatch)."""
    if n_shards <= 1 or not fused_ops._is_uniform(dsched):
        return None
    s_n = int(n_shards)
    t = dsched.t_pad
    n_t = dsched.n_tiles0
    n_j = dsched.n_j
    wf0, wf1 = sched.wavefronts

    # ---- wavefront 0: Eq-3-balanced contiguous tile partition ----
    costs0 = cost_model.tile_costs_batch(
        a, [tl.i_start for tl in wf0], [tl.i_end for tl in wf0],
        [tl.j_rows for tl in wf0], b_col, c_col, b_is_sparse,
        width_cap=width_cap)
    tile_bounds = balanced_contiguous_partition(costs0, s_n)
    per = np.diff(tile_bounds)
    t0s = max(int(per.max()) if per.size else 0, 1)
    tile_map = np.full((s_n, t0s), n_t, dtype=np.int64)
    for s in range(s_n):
        ids = np.arange(tile_bounds[s], tile_bounds[s + 1], dtype=np.int64)
        tile_map[s, : ids.size] = ids
    tile_map = tile_map.reshape(-1)

    j_rows0 = _pad_gather(dsched.j_rows0, tile_map, n_j)
    ell_cols0 = _pad_gather(dsched.ell_cols0, tile_map, 0)
    ell_vals0 = _pad_gather(dsched.ell_vals0, tile_map, 0)

    valid = tile_map < n_t
    row_map = (np.where(valid, tile_map, 0)[:, None] * t
               + np.arange(t, dtype=np.int64)[None, :])
    row_map = np.where(valid[:, None], row_map, 0).reshape(-1)

    # ---- halo: owner of each wavefront-1 dependency row ----
    halo_rows = dsched.wf1_dep_rows()
    h = int(halo_rows.shape[0])
    row_bounds = tile_bounds * t
    if h:
        owner = np.searchsorted(row_bounds, halo_rows, side="right") - 1
        owner = np.clip(owner, 0, s_n - 1)
        counts = np.bincount(owner, minlength=s_n)
        hs = max(int(counts.max()), 1)
        offsets = np.concatenate([[0], np.cumsum(counts)])
        # halo_rows is sorted and ownership is contiguous, so rows of one
        # shard are consecutive; slot = rank within the shard's run
        slot = np.arange(h, dtype=np.int64) - offsets[owner]
        send_local = np.zeros((s_n, hs), dtype=np.int32)
        send_pos = np.full((s_n, hs), h, dtype=np.int32)
        send_local[owner, slot] = (halo_rows - row_bounds[owner]).astype(
            np.int32)
        send_pos[owner, slot] = np.arange(h, dtype=np.int32)
    else:
        hs = 1
        send_local = np.zeros((s_n, 1), dtype=np.int32)
        send_pos = np.full((s_n, 1), 0, dtype=np.int32)

    # ---- wavefront 1: cost-balanced tile partition + halo remap ----
    n_t1 = dsched.n_tiles1
    if n_t1:
        costs1 = cost_model.tile_costs_batch(
            a, np.zeros(n_t1, np.int64), np.zeros(n_t1, np.int64),
            [tl.j_rows for tl in wf1], b_col, c_col, b_is_sparse,
            width_cap=width_cap)
        bounds1 = balanced_contiguous_partition(costs1, s_n)
        per1 = np.diff(bounds1)
        t1s = max(int(per1.max()), 1)
        tmap1 = np.full((s_n, t1s), n_t1, dtype=np.int64)
        for s in range(s_n):
            ids = np.arange(bounds1[s], bounds1[s + 1], dtype=np.int64)
            tmap1[s, : ids.size] = ids
        tmap1 = tmap1.reshape(-1)
        j_rows1 = _pad_gather(dsched.j_rows1, tmap1, n_j)
        cols1 = _pad_gather(dsched.ell_cols1, tmap1, 0)
        vals1 = _pad_gather(dsched.ell_vals1, tmap1, 0)
        cols1 = _remap_to_halo(cols1, halo_rows)
    else:
        t1s = 0
        j_rows1 = np.full((0, 1), n_j, dtype=np.int32)
        cols1 = np.zeros((0, 1, 1), dtype=np.int32)
        vals1 = np.zeros((0, 1, 1), dtype=np.float32)

    # ---- spill lanes: even split (each lane is one scatter-add) ----
    n_sp = int(dsched.spill_rows1.shape[0])
    sp_l = -(-n_sp // s_n) if n_sp else 0
    spill_rows = np.full(s_n * max(sp_l, 1) if n_sp else 0, n_j, np.int32)
    spill_cols = np.zeros(spill_rows.shape[0], np.int32)
    spill_vals = np.zeros(spill_rows.shape[0], np.float32)
    if n_sp:
        sp_remap = _remap_to_halo(dsched.spill_cols1, halo_rows)
        for s in range(s_n):
            lo, hi_ = s * sp_l, min((s + 1) * sp_l, n_sp)
            if lo >= n_sp:
                break
            dst = s * sp_l
            spill_rows[dst: dst + hi_ - lo] = dsched.spill_rows1[lo:hi_]
            spill_cols[dst: dst + hi_ - lo] = sp_remap[lo:hi_]
            spill_vals[dst: dst + hi_ - lo] = dsched.spill_vals1[lo:hi_]

    comm = cost_model.shard_comm_model(s_n, h, dsched.n_i, c_col,
                                       n_j=n_j)
    return ShardedSchedule(
        n_shards=s_n, t_pad=t, n_i=dsched.n_i, n_j=n_j, n_tiles0=n_t,
        tiles_per_shard=t0s, tile_bounds=tile_bounds, tile_map=tile_map,
        row_map=row_map,
        j_rows0=j_rows0, ell_cols0=ell_cols0, ell_vals0=ell_vals0,
        wf1_per_shard=t1s, j_rows1=j_rows1, ell_cols1=cols1,
        ell_vals1=vals1,
        spill_per_shard=sp_l, spill_rows1=spill_rows,
        spill_cols1=spill_cols, spill_vals1=spill_vals,
        halo_rows=halo_rows, send_per_shard=hs,
        send_local=send_local.reshape(-1), send_pos=send_pos,
        comm_model=comm,
    )


# --------------------------------------------------------------------------
# Executors
# --------------------------------------------------------------------------
def _shard_executor(shard: ShardedSchedule, mesh, kind: str):
    """Build (and memoize per (mesh, kind)) the jitted shard_map executor.

    The schedule's index arrays are closed over as constants — they are
    part of the (cached) schedule, so jit's tracing cache stays hot across
    calls with the same operand shapes."""
    memo = getattr(shard, "_exec_memo", None)
    if memo is None:
        memo = {}
        object.__setattr__(shard, "_exec_memo", memo)
    key = (mesh, kind)
    fn = memo.get(key)
    if fn is not None:
        return fn

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ...models.sharding import shard_map

    axes = tuple(mesh.axis_names)
    sh = P(axes)            # leading dim carries the flattened shard axis
    rep = P()
    t, t0s = shard.t_pad, shard.tiles_per_shard
    t1s, sp_l = shard.wf1_per_shard, shard.spill_per_shard
    n_j, h = shard.n_j, shard.halo_size
    # index arrays are dtype-independent: convert (and upload) once at
    # build time, not per call — only the value arrays depend on the
    # operands' dtype and get their own tiny per-dtype memo below
    send_pos = jnp.asarray(shard.send_pos)           # replicated constant
    idx_args = (jnp.asarray(shard.j_rows0), jnp.asarray(shard.ell_cols0),
                jnp.asarray(shard.j_rows1), jnp.asarray(shard.ell_cols1),
                jnp.asarray(shard.spill_rows1),
                jnp.asarray(shard.spill_cols1),
                jnp.asarray(shard.send_local))
    vals_by_dtype: dict = {}

    def wf1_and_combine(d, d1_local, j_rows1_s, cols1_s, vals1_s,
                        srows_s, scols_s, svals_s, send_local_s):
        """Halo all-gather + this shard's wavefront-1 share, then psum."""
        c_col = d.shape[1]
        if h:
            contrib = d1_local[send_local_s]              # (Hs, c_col)
            gathered = jax.lax.all_gather(contrib, axes)  # (S, Hs, c_col)
            halo = jnp.zeros((h, c_col), d.dtype).at[
                send_pos.reshape(-1)].set(
                gathered.reshape(-1, c_col), mode="drop")
            if t1s:
                rows1 = fused_ops._ell_rows(cols1_s, vals1_s, halo)
                d = d.at[j_rows1_s.reshape(-1)].set(
                    rows1.reshape(-1, c_col), mode="drop")
            if sp_l:
                d = d.at[srows_s].add(
                    svals_s.astype(d.dtype)[:, None] * halo[scols_s])
        return jax.lax.psum(d, axes)

    def per_shard_gemm(b_blk, c, j_rows0_s, cols0_s, vals0_s, j_rows1_s,
                       cols1_s, vals1_s, srows_s, scols_s, svals_s,
                       send_local_s):
        c_col = c.shape[1]
        d1_t = b_blk.reshape(t0s, t, -1) @ c              # (T0s, t, c_col)
        rows0 = jax.vmap(fused_ops._ell_rows)(cols0_s, vals0_s, d1_t)
        d = jnp.zeros((n_j, c_col), c.dtype).at[
            j_rows0_s.reshape(-1)].set(rows0.reshape(-1, c_col),
                                       mode="drop")
        return wf1_and_combine(d, d1_t.reshape(t0s * t, c_col), j_rows1_s,
                               cols1_s, vals1_s, srows_s, scols_s, svals_s,
                               send_local_s)

    def per_shard_spmm(o_cols_s, o_vals_s, d1_spill_s, c, j_rows0_s,
                       cols0_s, vals0_s, j_rows1_s, cols1_s, vals1_s,
                       srows_s, scols_s, svals_s, send_local_s):
        c_col = c.shape[1]
        # op-1 SpMM per tile: hybrid ELL body over replicated C + the
        # tile's pre-accumulated spill delta
        d1_t = fused_ops._ell_rows(o_cols_s, o_vals_s, c) \
            + d1_spill_s.reshape(t0s, t, c_col)
        rows0 = jax.vmap(fused_ops._ell_rows)(cols0_s, vals0_s, d1_t)
        d = jnp.zeros((n_j, c_col), c.dtype).at[
            j_rows0_s.reshape(-1)].set(rows0.reshape(-1, c_col),
                                       mode="drop")
        return wf1_and_combine(d, d1_t.reshape(t0s * t, c_col), j_rows1_s,
                               cols1_s, vals1_s, srows_s, scols_s, svals_s,
                               send_local_s)

    if kind == "gemm":
        body, n_sharded_lead = per_shard_gemm, 1
    else:
        body, n_sharded_lead = per_shard_spmm, 3
    # operand specs: leading sharded inputs, then replicated C, then the
    # schedule's 10 stacked index arrays (all sharded on dim 0)
    in_specs = (sh,) * n_sharded_lead + (rep,) + (sh,) * 10
    mapped = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=rep)
    fn = jax.jit(mapped)

    def run(*operands):
        dtype = operands[-1].dtype                  # C is the last operand
        vals = vals_by_dtype.get(dtype)
        if vals is None:
            vals = (jnp.asarray(shard.ell_vals0, dtype),
                    jnp.asarray(shard.ell_vals1, dtype),
                    jnp.asarray(shard.spill_vals1, dtype))
            vals_by_dtype[dtype] = vals
        j_rows0, cols0, j_rows1_a, cols1_a, srows, scols, send_local = \
            idx_args
        args = operands + (j_rows0, cols0, vals[0], j_rows1_a, cols1_a,
                           vals[1], srows, scols, vals[2], send_local)
        return fn(*args)

    memo[key] = run
    return run


def _row_map_device(shard: ShardedSchedule):
    """``shard.row_map`` as a device array, uploaded once per schedule."""
    import jax.numpy as jnp
    rm = getattr(shard, "_row_map_jax", None)
    if rm is None:
        rm = jnp.asarray(shard.row_map)
        object.__setattr__(shard, "_row_map_jax", rm)
    return rm


def sharded_gemm_spmm(shard: ShardedSchedule, mesh, b, c):
    """GeMM-SpMM over the mesh: B row-blocks follow the tile partition."""
    import jax.numpy as jnp
    b = jnp.asarray(b)
    if b.shape[0] != shard.n_i:
        raise ValueError(f"b has {b.shape[0]} rows, schedule expects "
                         f"{shard.n_i}")
    n_pad = shard.n_tiles0 * shard.t_pad
    b_pad = jnp.pad(b, ((0, n_pad - b.shape[0]), (0, 0)))
    b_blk = b_pad[_row_map_device(shard)]         # (S*T0s*t, b_col)
    run = _shard_executor(shard, mesh, "gemm")
    return run(b_blk, jnp.asarray(c))


def _op1_sharded(shard: ShardedSchedule, dsched: DeviceSchedule, a1: CSR,
                 dtype):
    """Shard-ordered op-1 hybrid pack as *device* arrays, memoized per
    (a1 content, cap, dtype) like ``fused_ops._op1_ell`` itself — the
    O(nnz) repack *and* the host-to-device upload happen once per
    schedule, not once per call (the op-1 arrays are the largest operands
    in the problem)."""
    import jax.numpy as jnp
    cap = dsched.width_cap
    memo_key = (csr_content_digest(a1),
                None if cap is None else int(cap), str(dtype))
    memo = getattr(shard, "_op1_memo", None)
    if memo is not None and memo[0] == memo_key:
        return memo[1]
    o_cols, o_vals, spill_flat, spill_cols, spill_vals = fused_ops._op1_ell(
        a1, dsched, width_cap=cap)
    # per-tile arrays -> shard order (pad tiles are zero ELL, a no-op)
    o_cols_s = _pad_gather(o_cols, shard.tile_map, 0)
    o_vals_s = _pad_gather(o_vals, shard.tile_map, 0)
    packed = (jnp.asarray(o_cols_s), jnp.asarray(o_vals_s, dtype),
              int(spill_flat.size), jnp.asarray(spill_flat),
              jnp.asarray(spill_cols), jnp.asarray(spill_vals, dtype))
    object.__setattr__(shard, "_op1_memo", (memo_key, packed))
    return packed


def sharded_spmm_spmm(shard: ShardedSchedule, dsched: DeviceSchedule,
                      mesh, a1: CSR, c):
    """SpMM-SpMM over the mesh: per-shard op-1 hybrid ELL against a
    replicated C; the op-1 spill delta is scattered globally then gathered
    into shard order with the same row map as the GeMM path's B blocks."""
    import jax.numpy as jnp
    c = jnp.asarray(c)
    if a1.n_rows != shard.n_i:
        raise ValueError(f"op-1 has {a1.n_rows} rows, schedule expects "
                         f"{shard.n_i}")
    if c.shape[0] != a1.n_cols:
        raise ValueError(f"c has {c.shape[0]} rows, op-1 has {a1.n_cols} "
                         f"columns")
    c_col = c.shape[1]
    o_cols_s, o_vals_s, n_spill, spill_flat, spill_cols, spill_vals = \
        _op1_sharded(shard, dsched, a1, c.dtype)
    n_pad = shard.n_tiles0 * shard.t_pad
    d1_spill = jnp.zeros((n_pad, c_col), c.dtype)
    if n_spill:
        d1_spill = d1_spill.at[spill_flat].add(
            spill_vals.astype(c.dtype)[:, None] * c[spill_cols])
    d1_spill_blk = d1_spill[_row_map_device(shard)]
    run = _shard_executor(shard, mesh, "spmm")
    return run(o_cols_s, o_vals_s, d1_spill_blk, c)
