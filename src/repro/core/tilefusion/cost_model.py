"""Data-movement cost model — Equation 3 of the paper, dtype-aware.

cost(T, bCol, cCol) = (nz(T) + uc(T) + t + |J|) * cCol + idx

  nz(T) : unique nonzeros in the tile from A (and B when sparse; when B is
          dense the tile's full B rows, t*bCol, are charged instead)
  uc(T) : nonzeros with unique columns in the tile (distinct D1/C rows touched
          by the tile's second-op iterations)
  t     : rows of D1 produced by the tile (first-op iterations)
  |J|   : fused second-op iterations (rows of D written)
  idx   : indexing cost for the sparse operand(s) (int32 per nonzero)

On TPU `cacheSize` is the per-core VMEM budget (DESIGN.md §2); the unit here
is *elements* scaled by dtype bytes so the same model serves f32/bf16/f64.
"""
from __future__ import annotations

import numpy as np

from ..sparse.formats import CSR

#: Default fast-memory budget: 64 MiB of the ~128 MiB v5e VMEM (leave half for
#: double-buffering and the matmul operands), expressed in bytes.
DEFAULT_VMEM_BUDGET_BYTES = 64 * 1024 * 1024

#: CPU-style default used by benchmarks mirroring the paper's setting
#: (L1+L2+L3/core on CascadeLake ~ 2.4 MB).
DEFAULT_CPU_CACHE_BYTES = int(2.4 * 1024 * 1024)


def tile_cost_elements(
    a: CSR,
    i_start: int,
    i_end: int,
    j_rows: np.ndarray,
    b_col: int,
    c_col: int,
    b_is_sparse: bool,
) -> float:
    """Eq 3 in elements (multiply by dtype bytes for a byte budget)."""
    t = max(i_end - i_start, 0)
    if j_rows.size:
        starts = a.indptr[j_rows]
        ends = a.indptr[j_rows + 1]
        nnz_a = int((ends - starts).sum())
        cols = np.concatenate([a.indices[s:e] for s, e in zip(starts, ends)]) \
            if nnz_a else np.zeros(0, np.int32)
        uc = int(np.unique(cols).shape[0])
    else:
        nnz_a, uc = 0, 0
    if b_is_sparse:
        # nonzeros of the B rows in [i_start, i_end) — approximated by the
        # same CSR when B == A (SpMM-SpMM case), else caller passes its own.
        nz_b = int(a.indptr[min(i_end, a.n_rows)] - a.indptr[min(i_start, a.n_rows)])
        nz = nnz_a + nz_b
        idx = nnz_a + nz_b  # int32 per nonzero
    else:
        nz = nnz_a + t * b_col  # dense B rows charged in full
        idx = nnz_a
    return float((nz + uc + t + j_rows.size) * c_col + idx)


def tile_cost_bytes(a, i_start, i_end, j_rows, b_col, c_col, b_is_sparse,
                    dtype_bytes: int = 4) -> float:
    return tile_cost_elements(a, i_start, i_end, j_rows, b_col, c_col,
                              b_is_sparse) * dtype_bytes
