"""Data-movement cost model — Equation 3 of the paper, dtype-aware.

cost(T, bCol, cCol) = (nz(T) + uc(T) + t + |J|) * cCol + idx

  nz(T) : unique nonzeros in the tile from A (and B when sparse; when B is
          dense the tile's full B rows, t*bCol, are charged instead)
  uc(T) : nonzeros with unique columns in the tile (distinct D1/C rows touched
          by the tile's second-op iterations)
  t     : rows of D1 produced by the tile (first-op iterations)
  |J|   : fused second-op iterations (rows of D written)
  idx   : indexing cost for the sparse operand(s) (int32 per nonzero)

On TPU `cacheSize` is the per-core VMEM budget (DESIGN.md §2); the unit here
is *elements* scaled by dtype bytes so the same model serves f32/bf16/f64.
"""
from __future__ import annotations

import numpy as np

from ..sparse.formats import CSR, csr_gather_rows

#: Elements a spilled hybrid-ELL entry streams (row, col, val) vs the 2
#: (col, val) of a body slot — shared by the packer's cap search
#: (``formats.hybrid_width_cap``) and the pricing here.
SPILL_ELEMENTS = 3

#: Bytes of one sparse index (int32) — index traffic does NOT scale with the
#: value dtype, so byte-level pricing charges it separately from the
#: ``dtype_bytes`` value traffic.
INDEX_BYTES = 4


def operand_dtype_bytes(*operands, default: int = 4) -> int:
    """Itemsize of the first operand that has a dtype (the dense operand's
    itemsize is what every byte price in the system should scale with —
    bf16 operands move half the bytes of f32, f64 twice).  Non-array
    operands (e.g. a CSR op-1) are skipped; ``default`` covers the
    all-sparse / empty case."""
    for op in operands:
        dt = getattr(op, "dtype", None)
        if dt is not None:
            try:
                return int(np.dtype(dt).itemsize)
            except TypeError:
                continue
    return int(default)

#: Default fast-memory budget: 64 MiB of the ~128 MiB v5e VMEM (leave half for
#: double-buffering and the matmul operands), expressed in bytes.
DEFAULT_VMEM_BUDGET_BYTES = 64 * 1024 * 1024

#: CPU-style default used by benchmarks mirroring the paper's setting
#: (L1+L2+L3/core on CascadeLake ~ 2.4 MB).
DEFAULT_CPU_CACHE_BYTES = int(2.4 * 1024 * 1024)


def hybrid_packed_elements(counts: np.ndarray, cap: int | None) -> int:
    """Value slots a HybridELL pack of rows with nonzero ``counts`` streams:
    padded body (``n_rows * width``) plus ``SPILL_ELEMENTS`` per spilled
    entry.  ``cap=None`` means pad-to-max (no spill)."""
    counts = np.asarray(counts, dtype=np.int64)
    if counts.size == 0:
        return 0
    w_max = max(int(counts.max()), 1)
    w = w_max if cap is None else max(min(int(cap), w_max), 1)
    spill = int(np.maximum(counts - w, 0).sum())
    return int(counts.shape[0]) * w + SPILL_ELEMENTS * spill


def _row_counts(a: CSR) -> np.ndarray:
    """Per-row nonzero counts, memoized per CSR instance (immutable, like
    ``row_extents``) — the capped Eq-3 pricing reads them on every tile."""
    rc = getattr(a, "_row_counts", None)
    if rc is None:
        rc = np.diff(a.indptr).astype(np.int64)
        object.__setattr__(a, "_row_counts", rc)
    return rc


def _spill_cumsum(a: CSR, w: int) -> np.ndarray:
    """``cs[i] = Σ_{r<i} max(counts[r] - w, 0)``, memoized per (matrix, w):
    any row range's spill count is one subtraction, so the recursive step-2
    split pays O(1) per tile instead of re-diffing the whole indptr."""
    cache = getattr(a, "_spill_cumsum_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(a, "_spill_cumsum_cache", cache)
    cs = cache.get(w)
    if cs is None:
        cs = np.concatenate(
            [[0], np.cumsum(np.maximum(_row_counts(a) - w, 0))])
        cache[w] = cs
    return cs


def _capped_body_width(a: CSR, width_cap: int) -> int:
    counts = _row_counts(a)
    w_max = max(int(counts.max()), 1) if counts.size else 1
    return max(min(int(width_cap), w_max), 1)


def _op1_packed_range(a: CSR, lo: int, hi: int, width_cap: int) -> int:
    """Capped-width op-1 charge for rows [lo, hi): body slots at the global
    capped width plus the range's spill entries (3 elements each)."""
    w = _capped_body_width(a, width_cap)
    cs = _spill_cumsum(a, w)
    return (hi - lo) * w + SPILL_ELEMENTS * int(cs[hi] - cs[lo])


def tile_cost_elements(
    a: CSR,
    i_start: int,
    i_end: int,
    j_rows: np.ndarray,
    b_col: int,
    c_col: int,
    b_is_sparse: bool,
    width_cap: int | None = None,
) -> float:
    """Eq 3 in elements (multiply by dtype bytes for a byte budget).

    ``width_cap`` (sparse-B only): price the op-1 operand as the hybrid-ELL
    traffic the executor actually streams — body rows padded to the capped
    width plus 3 elements per spilled entry — instead of the raw nonzero
    count.  ``None`` keeps the paper's idealized nnz charge."""
    t = max(i_end - i_start, 0)
    if j_rows.size:
        # one flat gather of the tile's A entries (no per-row concatenate)
        flat, lens = csr_gather_rows(a, j_rows)
        nnz_a = int(lens.sum())
        uc = int(np.unique(a.indices[flat]).shape[0]) if nnz_a else 0
    else:
        nnz_a, uc = 0, 0
    if b_is_sparse:
        # nonzeros of the B rows in [i_start, i_end) — approximated by the
        # same CSR when B == A (SpMM-SpMM case), else caller passes its own.
        lo, hi = min(i_start, a.n_rows), min(i_end, a.n_rows)
        if width_cap is None:
            nz_b = int(a.indptr[hi] - a.indptr[lo])
        else:
            nz_b = _op1_packed_range(a, lo, hi, width_cap)
        nz = nnz_a + nz_b
        idx = nnz_a + nz_b  # int32 per nonzero
    else:
        nz = nnz_a + t * b_col  # dense B rows charged in full
        idx = nnz_a
    return float((nz + uc + t + j_rows.size) * c_col + idx)


def tile_costs_batch(
    a: CSR,
    i_starts: np.ndarray,
    i_ends: np.ndarray,
    j_rows_list,
    b_col: int,
    c_col: int,
    b_is_sparse: bool,
    width_cap: int | None = None,
) -> np.ndarray:
    """Eq 3 for many tiles in one vectorized pass.

    Element-for-element identical to calling ``tile_cost_elements`` per
    tile, but O(total nnz log nnz) instead of a Python loop: per-tile nnz
    comes from a bincount over tile ids, and per-tile unique-column counts
    from one sort of ``tile_id * n_cols + col`` keys.  The scheduler's
    step-2 loops (uniform halving, split entry, wavefront-1 balance) call
    this once per candidate set instead of once per tile.
    """
    n_t = len(j_rows_list)
    if n_t == 0:
        return np.zeros(0, np.float64)
    i_starts = np.asarray(i_starts, dtype=np.int64)
    i_ends = np.asarray(i_ends, dtype=np.int64)
    t = np.maximum(i_ends - i_starts, 0)
    sizes = np.asarray([jr.size for jr in j_rows_list], dtype=np.int64)
    all_j = np.concatenate(j_rows_list).astype(np.int64)
    nnz_a = np.zeros(n_t, dtype=np.int64)
    uc = np.zeros(n_t, dtype=np.int64)
    if all_j.size:
        tile_of = np.repeat(np.arange(n_t, dtype=np.int64), sizes)
        flat, lens = csr_gather_rows(a, all_j)
        nnz_a = np.bincount(tile_of, weights=lens,
                            minlength=n_t).astype(np.int64)
        if flat.size:
            keys = (np.repeat(tile_of, lens) * np.int64(a.n_cols)
                    + a.indices[flat])
            uniq = np.unique(keys)
            uc = np.bincount(uniq // np.int64(a.n_cols),
                             minlength=n_t).astype(np.int64)
    if b_is_sparse:
        lo = np.minimum(i_starts, a.n_rows)
        hi = np.minimum(i_ends, a.n_rows)
        if width_cap is None:
            nz_b = (a.indptr[hi] - a.indptr[lo]).astype(np.int64)
        else:
            w = _capped_body_width(a, width_cap)
            sp_cum = _spill_cumsum(a, w)
            nz_b = ((hi - lo) * w
                    + SPILL_ELEMENTS * (sp_cum[hi] - sp_cum[lo]))
        nz = nnz_a + nz_b
        idx = nnz_a + nz_b
    else:
        nz = nnz_a + t * b_col
        idx = nnz_a
    return ((nz + uc + t + sizes) * c_col + idx).astype(np.float64)


#: Fraction of the modeled wavefront-0 streaming time the async halo
#: all-gather can realistically hide under.  wf0 is communication-free by
#: the fusion criterion, but the gather is issued *after* the halo rows'
#: own D1 contributions are computed (the duplicate-compute prologue), so
#: only part of the wf0 window remains to overlap into; 0.5 is the
#: conservative half-window used by the pricing below.
OVERLAP_WINDOW_RATIO = 0.5


def shard_comm_model(n_shards: int, halo_rows: int, n_i: int, c_col: int,
                     dtype_bytes: int = 4, n_j: int | None = None,
                     n_repl: int = 1,
                     combine_rows: int | None = None,
                     n_depth: int = 1,
                     overlap: bool | str = False,
                     wf0_bytes: float = 0.0) -> dict:
    """Communication terms of the sharded dispatch: ``n_shards`` row-block
    shards of the wavefront-0 tile grid × ``n_repl`` column replicas of the
    dense operand (the 1.5D layout; ``n_repl=1`` is the pure-1D partition).

    Wavefront 0 is communication-free (the fusion criterion makes every
    fused row's dependencies tile-local, hence shard-local).  Each column
    replica carries ``c_col / n_repl`` columns of C/D1/D, so every term
    below shrinks with replication — the price is memory, not bytes on the
    wire: the sparse operand and B are stored ``n_repl`` times
    (``choose_mesh_layout`` weighs the two).  Terms:

      ``halo_bytes``       all-gather of just the wavefront-1 halo — the
                           ``halo_rows`` D1 rows the post-barrier wavefront
                           reads: every device receives the (S-1)/S
                           fraction it doesn't own.
      ``combine_bytes``    the *psum* output combine: each shard's rows of
                           D are disjoint but scattered (fused rows follow
                           the pattern, not contiguous blocks), so the
                           psum executors all-reduce the full
                           ``(n_j, c_col)`` partial — the dominant term
                           for small halos.
      ``combine_bytes_reduce_scatter``
                           the row-remapped reduce-scatter combine: D rows
                           are permuted so every shard owns one contiguous
                           block (``combine_rows`` = padded permuted row
                           count, ≈ n_j); partials are owner-disjoint, so
                           each block crosses the wire exactly once when
                           the output is consumed instead of every row
                           reaching every device.
      ``replicate_bytes``  the alternative to the halo exchange —
                           all-gather the full D1 so wavefront 1 needs no
                           index sets (or, equivalently, replicate op-1
                           compute).

    ``combine`` is the model's choice between the two combine strategies
    (fewest bytes wins; ties keep the simpler psum).  ``halo_fraction``
    (halo / full D1) is the exchange-strategy decision variable: a near-1
    fraction says the pattern scatters its wavefront-1 deps so widely that
    replication costs the same bytes and saves the index bookkeeping.

    2.5D (``n_depth > 1``): the wavefront-1 tiles and spill lanes are
    split over ``n_depth`` layers that each gather a 1/n_depth slice of
    the halo in parallel (the staged exchange), so the total halo bytes
    are unchanged but every device moves ``1/n_depth`` of its 1.5D share;
    the partial D blocks are then psum-combined over the depth axis
    (``depth_combine_bytes``).  Overlap (``overlap=True`` or ``"auto"``):
    the halo gather is issued *before* the wf0 body, hiding per-device
    halo bytes up to ``OVERLAP_WINDOW_RATIO`` of the modeled per-device
    wf0 streaming (``wf0_bytes`` total, split over the s·r compute grid);
    bytes beyond the window cost full rate.  The price of overlap is the
    duplicate halo-row compute prologue (``overlap_dup_bytes``);
    ``overlap="auto"`` enables it iff the hidden bytes beat the duplicate
    compute.  ``critical_bytes`` is the per-device effective communication
    on the critical path — the number layout comparisons should rank."""
    s = max(int(n_shards), 1)
    r = max(int(n_repl), 1)
    z = max(int(n_depth), 1)
    remote = (s - 1) / s
    cc_r = c_col / r                     # columns per replica group
    out_rows = float(n_i if n_j is None else n_j)
    perm_rows = out_rows if combine_rows is None else float(combine_rows)
    halo = float(halo_rows) * cc_r * dtype_bytes * remote * s * r
    full = float(n_i) * cc_r * dtype_bytes * remote * s * r
    combine = out_rows * cc_r * dtype_bytes * remote * s * r
    combine_rs = perm_rows * cc_r * dtype_bytes * remote * r
    combine_choice = min(combine, combine_rs)
    # 2.5D terms: per-device halo shrinks 1/z; depth layers psum partials.
    halo_per_dev = halo / (s * r * z)
    depth_combine = perm_rows * cc_r * dtype_bytes * (z - 1) * r
    # Overlap window: per-device wf0 streaming share, discounted to the
    # fraction the post-prologue gather can actually hide under.
    window = (float(wf0_bytes) / (s * r)) * OVERLAP_WINDOW_RATIO
    halo_eff_per_dev = max(halo_per_dev - window, 0.0)
    saving = (halo_per_dev - halo_eff_per_dev) * s * r * z
    # Duplicate-compute prologue: every replica fiber recomputes the halo
    # rows' D1 values ahead of the gather (charged at the value dtype).
    dup = float(halo_rows) * cc_r * dtype_bytes * r
    if isinstance(overlap, str):
        overlap_on = saving > dup
    else:
        overlap_on = bool(overlap)
    if not overlap_on:
        halo_eff_per_dev = halo_per_dev
        saving = 0.0
    halo_eff = halo_eff_per_dev * s * r * z
    critical = (halo_eff_per_dev + combine_choice / (s * r)
                + depth_combine / (s * r * z)
                + (dup / (s * r * z) if overlap_on else 0.0))
    return {
        "n_shards": s,
        "n_repl": r,
        "n_depth": z,
        "halo_rows": int(halo_rows),
        "halo_bytes": halo,
        "halo_bytes_per_device": halo_per_dev,
        "halo_bytes_effective": halo_eff,
        "combine_bytes": combine,
        "combine_bytes_reduce_scatter": combine_rs,
        "combine": "reduce_scatter" if combine_rs < combine else "psum",
        "depth_combine_bytes": depth_combine,
        "replicate_bytes": full,
        "halo_fraction": float(halo_rows) / max(n_i, 1),
        "overlap": overlap_on,
        "overlap_saving_bytes": saving,
        "overlap_dup_bytes": dup if overlap_on else 0.0,
        "critical_bytes": critical,
        "layout": ("2.5d" if z > 1 else ("1d" if r == 1 else "1.5d")),
    }


def choose_mesh_layout(mesh_shape, *, halo_rows: int, n_i: int, n_j: int,
                       c_col: int, operand_bytes: float,
                       dtype_bytes: int = 4,
                       serial_bytes: float = 0.0,
                       overlap: bool | str = False,
                       wf0_bytes: float = 0.0) -> dict:
    """How the sharded dispatch should use a mesh's axes: pure-1D (flatten
    every axis into row-block shards) vs replicated-1.5D (leading axis row
    shards, trailing axes column replicas of the dense operand) vs 2.5D
    (a third depth axis replicating wf0 and splitting the halo exchange),
    vs not sharding at all (``"fallback"``, priced only when the caller
    supplies the serial Eq-3 traffic via ``serial_bytes``).

    The replication ladder of Bharadwaj et al. trades memory for
    communication: with ``n_repl`` column replicas each device stores the
    sparse operand and B ``n_repl`` times over
    (``replication_cost_bytes``) but moves only ``c_col / n_repl`` columns
    of halo and combine traffic; a depth factor ``n_depth`` further splits
    the per-device halo (at the price of full wf0 replication and a depth
    psum).  Candidates are ranked on a *per-device* total: the compute
    share (``serial_bytes`` over the s·r compute grid — depth replicates
    wf0, it does not shrink compute) plus the per-device critical
    communication from ``shard_comm_model`` (overlap-discounted when
    ``overlap`` is on or ``"auto"``) plus the extra operand copies.

    Returns ``{"layout", "n_row", "n_repl", "n_depth", "overlap",
    "candidates"}`` where ``candidates`` maps each layout to its modeled
    cost terms."""
    shape = tuple(int(x) for x in mesh_shape)
    total = 1
    for x in shape:
        total *= x

    def cost(n_row: int, n_repl: int, n_depth: int = 1) -> dict:
        m = shard_comm_model(n_row, halo_rows, n_i, c_col,
                             dtype_bytes=dtype_bytes, n_j=n_j,
                             n_repl=n_repl, n_depth=n_depth,
                             overlap=overlap, wf0_bytes=wf0_bytes)
        comm = (m["halo_bytes_effective"]
                + min(m["combine_bytes"],
                      m["combine_bytes_reduce_scatter"])
                + m["depth_combine_bytes"])
        repl_cost = float(operand_bytes) * (n_repl * n_depth - 1)
        compute = float(serial_bytes) / (n_row * n_repl)
        n_dev = n_row * n_repl * max(n_depth, 1)
        return {"comm_bytes": comm, "replication_cost_bytes": repl_cost,
                "critical_bytes": m["critical_bytes"],
                "compute_bytes_per_device": compute,
                "total_bytes": comm + repl_cost,
                "total_per_device": (compute + m["critical_bytes"]
                                     + repl_cost / n_dev),
                "overlap": m["overlap"],
                "n_row": n_row, "n_repl": n_repl, "n_depth": n_depth}

    candidates = {"1d": cost(total, 1)}
    if len(shape) >= 2 and total > shape[0]:
        candidates["1.5d"] = cost(shape[0], total // shape[0])
    from .scheduler import resolve_mesh_layout
    r25 = resolve_mesh_layout(shape, "2.5d")
    if r25[2] > 1:
        candidates["2.5d"] = cost(*r25)
    if serial_bytes > 0.0:
        candidates["fallback"] = {
            "comm_bytes": 0.0, "replication_cost_bytes": 0.0,
            "critical_bytes": 0.0,
            "compute_bytes_per_device": float(serial_bytes),
            "total_bytes": float(serial_bytes),
            "total_per_device": float(serial_bytes),
            "overlap": False, "n_row": 1, "n_repl": 1, "n_depth": 1}
    # Rank on per-device totals when compute is priced; fall back to the
    # pure-bytes total (the pre-2.5D ranking rule) otherwise.
    rank_key = "total_per_device" if serial_bytes > 0.0 else "total_bytes"
    layout = min(candidates, key=lambda k: candidates[k][rank_key])
    best = candidates[layout]
    return {"layout": layout, "n_row": best["n_row"],
            "n_repl": best["n_repl"], "n_depth": best["n_depth"],
            "overlap": best["overlap"], "candidates": candidates}


#: Element-moves one inspected nonzero costs end to end (Algorithm 1 pass
#: + device ELL pack + traffic model), calibrated from inspector_bench on
#: the vectorized pipeline — the amortized side of the bucket price.
INSPECT_ELEMENTS_PER_NNZ = 40.0


def serving_bucket_price(*, n_rows: int, n_pad: int, nnz: int, b_col: int,
                         c_col: int, expected_reuse: float = 8.0,
                         inspect_elements_per_nnz: float =
                         INSPECT_ELEMENTS_PER_NNZ) -> dict:
    """Eq-3-style price of serving a request padded into a shape bucket of
    ``n_pad`` rows vs re-inspecting its exact shape.

    Padding charge (paid on *every* call): the ``n_pad - n_rows`` appended
    empty rows still stream their dense-B rows and D writes —
    ``extra * (b_col + c_col)`` elements of pure overhead per call.
    Inspection charge (amortized): the O(nnz) Algorithm-1 inspection +
    device pack, priced at ``inspect_elements_per_nnz`` element-moves per
    nonzero and paid once per ``expected_reuse`` calls of the bucket's
    resident schedule.  ``bucketed`` says the per-call padding traffic
    undercuts the per-call inspection share; ``break_even_reuse`` is the
    reuse count at which the two sides tie (above it, bucket)."""
    extra = max(int(n_pad) - int(n_rows), 0)
    pad_elements = float(extra) * (float(b_col) + float(c_col))
    inspect_elements = float(max(int(nnz), 1)) * float(
        inspect_elements_per_nnz)
    per_call_inspect = inspect_elements / max(float(expected_reuse), 1.0)
    return {
        "pad_elements_per_call": pad_elements,
        "inspect_elements_per_call": per_call_inspect,
        "bucketed": pad_elements <= per_call_inspect,
        "break_even_reuse": inspect_elements / max(pad_elements, 1.0),
    }


def reorder_gain(base_tm: dict, perm_tm: dict) -> float:
    """Relative Eq-3 fused-traffic saving of a permuted schedule over the
    identity ordering — ``1 - fused_bytes'/fused_bytes``, the quantity
    ``api._priced_reorder`` holds against ``MIN_TRAFFIC_SAVING`` before
    baking a permutation into a cached entry.  Both dicts are
    ``hbm_traffic_model`` outputs (``fused_bytes`` aggregates the
    ``tile_costs_batch`` per-tile Eq-3 costs).  >= 0 means the reorder
    helps; a degenerate zero-traffic base reports 0 (never apply)."""
    base = float(base_tm["fused_bytes"])
    if base <= 0.0:
        return 0.0
    return 1.0 - float(perm_tm["fused_bytes"]) / base


def tile_cost_bytes(a, i_start, i_end, j_rows, b_col, c_col, b_is_sparse,
                    dtype_bytes: int = 4) -> float:
    return tile_cost_elements(a, i_start, i_end, j_rows, b_col, c_col,
                              b_is_sparse) * dtype_bytes


def spmm_bytes(nnz: int, n_rows: int, n_cols: int, c_col: int,
               dtype_bytes: int = 4) -> float:
    """Bytes one plain SpMM ``(n_rows × n_cols) @ (n_cols × c_col)``
    streams: the dense input and output plus the sparse operand's values
    (at the operand dtype) and indices (int32)."""
    return (float(n_cols + n_rows) * c_col + float(nnz)) * dtype_bytes \
        + float(nnz) * INDEX_BYTES


def train_step_traffic(forward_tm: dict, transpose_tm: dict, *, nnz: int,
                       n_i: int, n_j: int, c_col: int,
                       dtype_bytes: int = 4) -> dict:
    """Per-training-step traffic of the differentiable fused path.

    The backward of ``D = A·(B·C)`` is two sparse-dense products against
    ``Aᵀ`` (paper §4.2.3 applied to training): the fused
    ``dB = Aᵀ·(Ḋ·Cᵀ)`` — priced by the *transpose entry's* own Eq-3 model,
    which was inspected with the swapped (b_col, c_col) — plus the plain
    ``g1 = Aᵀ·Ḋ`` SpMM feeding ``dC = Bᵀ·g1``.  ``forward_tm`` /
    ``transpose_tm`` are the two entries' ``traffic_model`` dicts."""
    g1 = spmm_bytes(nnz, n_i, n_j, c_col, dtype_bytes)
    fwd = float(forward_tm["fused_bytes"])
    bwd = float(transpose_tm["fused_bytes"]) + g1
    bwd_unfused = float(transpose_tm["unfused_bytes"]) + g1
    return {
        "forward_bytes": fwd,
        "backward_bytes": bwd,
        "backward_unfused_bytes": bwd_unfused,
        "train_step_bytes": fwd + bwd,
        "backward_saving": 1.0 - bwd / max(bwd_unfused, 1.0),
    }
