"""Loop-based inspector reference — the pre-vectorization Algorithm 1.

The production inspector (``scheduler.py`` / ``schedule.py`` / the ELL
packers) is O(nnz) vectorized numpy.  This module retains the original
row-at-a-time implementations verbatim, for two jobs:

  * the parity property test (``tests/test_scheduler.py``) asserts the
    vectorized scheduler emits *identical* schedules and device arrays on
    random CSR patterns, so the rewrite can never drift semantically;
  * ``benchmarks/inspector_bench.py`` times it as the "before" of the
    inspector speedup (the §4.2.3 amortization argument needs the number).

Nothing outside tests/benchmarks should import this module.
"""
from __future__ import annotations

from typing import List

import numpy as np

from ..sparse.formats import CSR, HybridELL, TileELL
from .schedule import DeviceSchedule
from .scheduler import Schedule, Tile


# --------------------------------------------------------------------------
# Eq-3 cost (loop over fused rows)
# --------------------------------------------------------------------------
def tile_cost_elements_ref(a: CSR, i_start: int, i_end: int,
                           j_rows: np.ndarray, b_col: int, c_col: int,
                           b_is_sparse: bool) -> float:
    t = max(i_end - i_start, 0)
    if j_rows.size:
        starts = a.indptr[j_rows]
        ends = a.indptr[j_rows + 1]
        nnz_a = int((ends - starts).sum())
        cols = np.concatenate([a.indices[s:e] for s, e in zip(starts, ends)]) \
            if nnz_a else np.zeros(0, np.int32)
        uc = int(np.unique(cols).shape[0])
    else:
        nnz_a, uc = 0, 0
    if b_is_sparse:
        nz_b = int(a.indptr[min(i_end, a.n_rows)]
                   - a.indptr[min(i_start, a.n_rows)])
        nz = nnz_a + nz_b
        idx = nnz_a + nz_b
    else:
        nz = nnz_a + t * b_col
        idx = nnz_a
    return float((nz + uc + t + j_rows.size) * c_col + idx)


# --------------------------------------------------------------------------
# Algorithm 1 (row-at-a-time dependency test)
# --------------------------------------------------------------------------
def _fused_mask_ref(a: CSR, i_start: int, i_end: int,
                    j_candidates: np.ndarray) -> np.ndarray:
    out = np.zeros(j_candidates.shape[0], dtype=bool)
    for k, j in enumerate(j_candidates):
        lo, hi = int(a.indptr[j]), int(a.indptr[j + 1])
        cols = a.indices[lo:hi]
        out[k] = bool(cols.size == 0 or
                      ((cols >= i_start) & (cols < i_end)).all())
    return out


def _split_tile_ref(a: CSR, tile: Tile, b_col: int, c_col: int,
                    b_is_sparse: bool, cache_size: float,
                    demoted: list) -> List[Tile]:
    cost = tile_cost_elements_ref(a, tile.i_start, tile.i_end, tile.j_rows,
                                  b_col, c_col, b_is_sparse)
    if cost <= cache_size or tile.n_i <= 1:
        if cost > cache_size and tile.n_j > 0 and tile.n_i <= 1:
            keep = tile.j_rows[: max(tile.n_j // 2, 0)]
            demoted.append(tile.j_rows[keep.shape[0]:])
            return [Tile(tile.i_start, tile.i_end, keep)]
        return [tile]
    mid = tile.i_start + tile.n_i // 2
    mask_lo = _fused_mask_ref(a, tile.i_start, mid, tile.j_rows)
    mask_hi = _fused_mask_ref(a, mid, tile.i_end, tile.j_rows)
    j_lo = tile.j_rows[mask_lo]
    j_hi = tile.j_rows[mask_hi & ~mask_lo]
    spanning = tile.j_rows[~(mask_lo | mask_hi)]
    if spanning.size:
        demoted.append(spanning)
    lo = Tile(tile.i_start, mid, j_lo)
    hi = Tile(mid, tile.i_end, j_hi)
    return (_split_tile_ref(a, lo, b_col, c_col, b_is_sparse, cache_size,
                            demoted)
            + _split_tile_ref(a, hi, b_col, c_col, b_is_sparse, cache_size,
                              demoted))


def _split_wf1_tile_ref(a: CSR, j_rows: np.ndarray, b_col: int, c_col: int,
                        b_is_sparse: bool, cache_size: float) -> List[Tile]:
    cost = tile_cost_elements_ref(a, 0, 0, j_rows, b_col, c_col, b_is_sparse)
    if cost <= cache_size or j_rows.size <= 1:
        return [Tile(0, 0, j_rows)]
    mid = j_rows.size // 2
    return (_split_wf1_tile_ref(a, j_rows[:mid], b_col, c_col, b_is_sparse,
                                cache_size)
            + _split_wf1_tile_ref(a, j_rows[mid:], b_col, c_col, b_is_sparse,
                                  cache_size))


def _balance_ref(j_all: np.ndarray, t: int, p: int) -> List[np.ndarray]:
    if j_all.size == 0:
        return []
    n_tiles = max(p, -(-j_all.size // max(t, 1)))
    n_tiles = min(n_tiles, j_all.size)
    return [chunk.astype(np.int32)
            for chunk in np.array_split(np.sort(j_all), n_tiles)]


def _step1_ref(a: CSR, t: int, n_i: int, n_j: int):
    wf0: List[Tile] = []
    unfused: List[np.ndarray] = []
    for i0 in range(0, n_i, t):
        i1 = min(i0 + t, n_i)
        j_cand = np.arange(i0, min(i1, n_j), dtype=np.int32)
        if j_cand.size:
            m = _fused_mask_ref(a, i0, i1, j_cand)
            wf0.append(Tile(i0, i1, j_cand[m]))
            unfused.append(j_cand[~m])
        else:
            wf0.append(Tile(i0, i1, np.zeros(0, np.int32)))
    if n_j > n_i:
        unfused.append(np.arange(n_i, n_j, dtype=np.int32))
    return wf0, unfused


def build_schedule_ref(
    a: CSR,
    b_col: int,
    c_col: int,
    p: int = 8,
    cache_size: float = 600_000.0,
    ct_size: int = 2048,
    b_is_sparse: bool = False,
    uniform_split: bool = False,
) -> Schedule:
    """The original loop-based ``build_schedule`` (see scheduler.py docs)."""
    n_i = a.n_cols
    n_j = a.n_rows

    if -(-n_i // ct_size) >= p:
        t = ct_size
    else:
        t = max(-(-n_i // p), 1)

    if uniform_split:
        while True:
            wf0, unfused = _step1_ref(a, t, n_i, n_j)
            worst = max((tile_cost_elements_ref(a, tl.i_start, tl.i_end,
                                                tl.j_rows, b_col, c_col,
                                                b_is_sparse) for tl in wf0),
                        default=0.0)
            if worst <= cache_size or t <= 64:
                break
            t //= 2
        split_wf0, demoted = wf0, []
    else:
        wf0, unfused = _step1_ref(a, t, n_i, n_j)
        demoted = []
        split_wf0 = []
        for tl in wf0:
            split_wf0.extend(_split_tile_ref(a, tl, b_col, c_col, b_is_sparse,
                                             cache_size, demoted))

    j_wf1 = np.concatenate(unfused + demoted) if (unfused or demoted) \
        else np.zeros(0, np.int32)
    wf1: List[Tile] = []
    for chunk in _balance_ref(j_wf1, t, p):
        wf1.extend(_split_wf1_tile_ref(a, chunk, b_col, c_col, b_is_sparse,
                                       cache_size))

    sched = Schedule(wavefronts=[split_wf0, wf1], n_i=n_i, n_j=n_j, t=t)
    sched.validate()
    return sched


def fused_compute_ratio_ref(a: CSR, ct_size: int = 2048) -> float:
    n = a.n_rows
    fused_nnz = 0
    for i0 in range(0, a.n_cols, ct_size):
        i1 = min(i0 + ct_size, a.n_cols)
        j_cand = np.arange(i0, min(i1, n), dtype=np.int32)
        m = _fused_mask_ref(a, i0, i1, j_cand)
        for j in j_cand[m]:
            fused_nnz += int(a.indptr[j + 1] - a.indptr[j])
    return fused_nnz / max(a.nnz, 1)


# --------------------------------------------------------------------------
# ELL packers (doubly nested loops)
# --------------------------------------------------------------------------
def ell_arrays_ref(a: CSR, j_rows_list, j_max, pad_row, local_start=None):
    n_tiles = len(j_rows_list)
    widths = [
        int((a.indptr[jr + 1] - a.indptr[jr]).max()) if jr.size else 0
        for jr in j_rows_list
    ]
    w = max(widths + [1])
    j_rows = np.full((n_tiles, j_max), pad_row, dtype=np.int32)
    cols = np.zeros((n_tiles, j_max, w), dtype=np.int32)
    vals = np.zeros((n_tiles, j_max, w), dtype=np.float32)
    for v, jr in enumerate(j_rows_list):
        j_rows[v, : jr.size] = jr
        for k, j in enumerate(jr):
            lo, hi = int(a.indptr[j]), int(a.indptr[j + 1])
            c = a.indices[lo:hi]
            if local_start is not None:
                c = c - local_start[v]
            cols[v, k, : c.shape[0]] = c
            vals[v, k, : c.shape[0]] = a.data[lo:hi].astype(np.float32)
    return j_rows, cols, vals


def tile_ell_from_csr_rows_ref(a: CSR, rows: np.ndarray,
                               width: int | None = None) -> TileELL:
    counts = (a.indptr[rows + 1] - a.indptr[rows]).astype(np.int64)
    w = int(counts.max()) if width is None and rows.size else (width or 1)
    w = max(w, 1)
    cols = np.zeros((rows.shape[0], w), dtype=np.int32)
    vals = np.zeros((rows.shape[0], w), dtype=np.float64)
    for k, r in enumerate(rows):
        c, v = a.row(int(r))
        c, v = c[:w], v[:w]
        cols[k, : c.shape[0]] = c
        vals[k, : v.shape[0]] = v
    return TileELL(cols=cols, vals=vals)


def hybrid_ell_from_csr_rows_ref(a: CSR, rows: np.ndarray,
                                 cap: int | None = None) -> HybridELL:
    """Row-at-a-time ``HybridELL.from_csr_rows`` (pins the vectorized
    packer; spill entries appear in row order, tail slots in column order)."""
    from ..sparse.formats import hybrid_width_cap
    rows = np.asarray(rows, dtype=np.int64)
    counts = (a.indptr[rows + 1] - a.indptr[rows]).astype(np.int64)
    if cap is None:
        cap = hybrid_width_cap(counts)
    w_max = int(counts.max()) if rows.size else 1
    w = max(min(int(cap), max(w_max, 1)), 1)
    cols = np.zeros((rows.shape[0], w), dtype=np.int32)
    vals = np.zeros((rows.shape[0], w), dtype=np.float64)
    s_rows, s_cols, s_vals = [], [], []
    for k, r in enumerate(rows):
        c, v = a.row(int(r))
        cols[k, : min(c.shape[0], w)] = c[:w]
        vals[k, : min(v.shape[0], w)] = v[:w]
        for cc, vv in zip(c[w:], v[w:]):
            s_rows.append(k); s_cols.append(int(cc)); s_vals.append(vv)
    return HybridELL(
        cols=cols, vals=vals,
        spill_rows=np.asarray(s_rows, np.int32),
        spill_cols=np.asarray(s_cols, np.int32),
        spill_vals=np.asarray(s_vals, np.float64))


def op1_ell_ref(a1: CSR, dsched: DeviceSchedule):
    t_pad = dsched.t_pad
    n_t = dsched.n_tiles0
    counts = np.diff(a1.indptr)
    w = int(counts.max()) if counts.size else 1
    cols = np.zeros((n_t, t_pad, max(w, 1)), np.int32)
    vals = np.zeros((n_t, t_pad, max(w, 1)), np.float32)
    for v in range(n_t):
        i0, ln = int(dsched.i_starts[v]), int(dsched.i_lens[v])
        for k in range(ln):
            cc, vv = a1.row(i0 + k)
            cols[v, k, : cc.shape[0]] = cc
            vals[v, k, : cc.shape[0]] = vv
    return cols, vals


def to_device_schedule_ref(a: CSR, sched: Schedule) -> DeviceSchedule:
    """``to_device_schedule`` with the loop-based ELL packer."""
    wf0, wf1 = sched.wavefronts
    n_i, n_j = sched.n_i, sched.n_j

    t_pad = max([tl.n_i for tl in wf0] + [1])
    j0_max = max([tl.n_j for tl in wf0] + [1])
    i_starts = np.asarray([tl.i_start for tl in wf0], dtype=np.int32)
    i_lens = np.asarray([tl.n_i for tl in wf0], dtype=np.int32)
    j_rows0, cols0, vals0 = ell_arrays_ref(
        a, [tl.j_rows for tl in wf0], j0_max, pad_row=n_j,
        local_start=i_starts)

    if wf1:
        j1_max = max(tl.n_j for tl in wf1)
        j_rows1, cols1, vals1 = ell_arrays_ref(
            a, [tl.j_rows for tl in wf1], max(j1_max, 1), pad_row=n_j)
    else:
        j_rows1 = np.full((0, 1), n_j, dtype=np.int32)
        cols1 = np.zeros((0, 1, 1), dtype=np.int32)
        vals1 = np.zeros((0, 1, 1), dtype=np.float32)

    return DeviceSchedule(
        n_i=n_i, n_j=n_j, t_pad=int(t_pad),
        i_starts=i_starts, i_lens=i_lens,
        j_rows0=j_rows0, ell_cols0=cols0, ell_vals0=vals0,
        j_rows1=j_rows1, ell_cols1=cols1, ell_vals1=vals1,
    )
