"""Bandwidth-reducing row reordering — beyond-paper optimization.

The tile-fusion criterion (a second-op row fuses iff ALL its dependencies
fall inside one contiguous tile) makes the fused ratio a direct function of
the matrix bandwidth.  The paper takes the matrix ordering as given; a
reverse Cuthill-McKee (RCM) pass before scheduling concentrates each row's
neighbourhood into a contiguous range, raising the fused ratio on graph
matrices (the paper's weak case) at a one-off O(nnz log n) cost amortized
exactly like the scheduler itself.

Correctness: D = A(BC) with symmetric permutation P is
P·D = (P·A·Pᵀ)((P·B)·C) — the caller permutes A's rows/cols and B's rows,
and un-permutes D (`apply`/`undo` helpers).
"""
from __future__ import annotations

import numpy as np

from ..sparse.formats import CSR


def rcm_order(a: CSR) -> np.ndarray:
    """Reverse Cuthill-McKee permutation (perm[new] = old)."""
    n = a.n_rows
    deg = np.diff(a.indptr)
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    # process components in order of minimum degree seed
    seeds = np.argsort(deg, kind="stable")
    for seed in seeds:
        if visited[seed]:
            continue
        # BFS with degree-sorted neighbour expansion
        queue = [int(seed)]
        visited[seed] = True
        while queue:
            u = queue.pop(0)
            order[pos] = u
            pos += 1
            nbrs = a.indices[a.indptr[u]:a.indptr[u + 1]]
            nbrs = nbrs[~visited[nbrs]]
            if nbrs.size:
                visited[nbrs] = True
                nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
                queue.extend(int(x) for x in nbrs)
    assert pos == n
    return order[::-1].copy()          # the "reverse" in RCM


def permute_csr(a: CSR, perm: np.ndarray) -> CSR:
    """Symmetric permutation: A' = P A Pᵀ with perm[new] = old."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0])
    rows = np.repeat(np.arange(a.n_rows), np.diff(a.indptr))
    new_rows = inv[rows]
    new_cols = inv[a.indices]
    return CSR.from_coo(a.n_rows, a.n_cols, new_rows.astype(np.int64),
                        new_cols.astype(np.int64), a.data.copy())


def bandwidth(a: CSR) -> int:
    rows = np.repeat(np.arange(a.n_rows), np.diff(a.indptr))
    if rows.size == 0:
        return 0
    return int(np.abs(rows - a.indices).max())
