"""Bandwidth-reducing row reordering — beyond-paper optimization.

The tile-fusion criterion (a second-op row fuses iff ALL its dependencies
fall inside one contiguous tile) makes the fused ratio a direct function of
the matrix bandwidth.  The paper takes the matrix ordering as given; a
reverse Cuthill-McKee (RCM) pass before scheduling concentrates each row's
neighbourhood into a contiguous range, raising the fused ratio on graph
matrices (the paper's weak case) at a one-off O(nnz log n) cost amortized
exactly like the scheduler itself.  ``similarity_order`` is the binary-
row-merging alternative (arXiv 2206.06611): group rows whose column
support hits the same tile-granularity blocks, cheap and
rectangular-safe.

Correctness: D = A(BC) with symmetric permutation P is
P·D = (P·A·Pᵀ)((P·B)·C) — the caller permutes A's rows/cols and B's rows,
and un-permutes D.  Since ISSUE 10 callers normally never do this by hand:
``FusionSpec(reorder=...)`` makes the permutation a schedule transform
inside ``api.get_schedule`` (Eq-3-priced, baked into the cached entry).
"""
from __future__ import annotations

from collections import deque

import numpy as np

from ..sparse.formats import CSR, csr_content_digest


def _require_square(a: CSR, who: str) -> None:
    if a.n_rows != a.n_cols:
        raise ValueError(
            f"{who} requires a square matrix (symmetric permutation "
            f"P·A·Pᵀ); got ({a.n_rows}, {a.n_cols}).  For rectangular "
            f"matrices pass explicit row_perm=/col_perm= to permute_csr.")


def rcm_order(a: CSR) -> np.ndarray:
    """Reverse Cuthill-McKee permutation (perm[new] = old).

    Treats column ids as neighbour row ids, so the matrix must be square
    (raises otherwise — on a rectangular CSR the old code silently walked
    column ids as if they were rows).  BFS uses a deque: ``list.pop(0)``
    is O(n) per pop, turning near-single-component graphs O(n²).
    """
    _require_square(a, "rcm_order")
    n = a.n_rows
    deg = np.diff(a.indptr)
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    # process components in order of minimum degree seed
    seeds = np.argsort(deg, kind="stable")
    for seed in seeds:
        if visited[seed]:
            continue
        # BFS with degree-sorted neighbour expansion
        queue = deque((int(seed),))
        visited[seed] = True
        while queue:
            u = queue.popleft()
            order[pos] = u
            pos += 1
            nbrs = a.indices[a.indptr[u]:a.indptr[u + 1]]
            nbrs = nbrs[~visited[nbrs]]
            if nbrs.size:
                visited[nbrs] = True
                nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
                queue.extend(int(x) for x in nbrs)
    assert pos == n
    return order[::-1].copy()          # the "reverse" in RCM


def similarity_order(a: CSR, block: int = 64) -> np.ndarray:
    """Row ordering by column-support similarity (perm[new] = old).

    Binary-row-merging-style grouping (arXiv 2206.06611): each row gets a
    bitmask of the ``block``-granularity column blocks it touches, and
    rows are sorted lexicographically by that mask so rows with matching
    support land adjacent — the same locality the merge phase exploits,
    here used to pack fusable rows into the same tile.  O(nnz + n·words);
    rectangular-safe (it permutes rows only — pair with an identity
    column permutation, or use it on the row axis of a fused stack).
    """
    n = a.n_rows
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    n_blocks = max(-(-a.n_cols // max(int(block), 1)), 1)
    n_words = -(-n_blocks // 64)
    masks = np.zeros((n, n_words), dtype=np.uint64)
    if a.nnz:
        rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(a.indptr))
        blk = a.indices.astype(np.int64) // max(int(block), 1)
        word, bit = blk // 64, blk % 64
        np.bitwise_or.at(masks, (rows, word),
                         np.uint64(1) << bit.astype(np.uint64))
    # lexsort by mask words (most-significant word last = primary key)
    keys = tuple(masks[:, w] for w in range(n_words))
    return np.lexsort(keys).astype(np.int64)


def permute_csr(a: CSR, perm: np.ndarray | None = None, *,
                row_perm: np.ndarray | None = None,
                col_perm: np.ndarray | None = None) -> CSR:
    """Permute a CSR matrix.

    ``perm=`` is the symmetric sugar ``A' = P A Pᵀ`` with ``perm[new] =
    old`` — square matrices only (raises on rectangular: the old code
    indexed the n_rows-sized inverse by column ids, silently corrupting
    or crashing any ``n_rows != n_cols`` input).  For the general case
    pass ``row_perm=`` and/or ``col_perm=`` (each ``perm[new] = old``,
    sized by the respective axis).
    """
    if perm is not None:
        if row_perm is not None or col_perm is not None:
            raise ValueError("pass either perm= or row_perm=/col_perm=, "
                             "not both")
        _require_square(a, "permute_csr(perm=)")
        row_perm = col_perm = np.asarray(perm, dtype=np.int64)
    if row_perm is None and col_perm is None:
        return a
    rows = np.repeat(np.arange(a.n_rows), np.diff(a.indptr))
    if row_perm is not None:
        row_perm = np.asarray(row_perm, dtype=np.int64)
        if row_perm.shape[0] != a.n_rows:
            raise ValueError(f"row_perm has {row_perm.shape[0]} entries "
                             f"for {a.n_rows} rows")
        inv_r = np.empty_like(row_perm)
        inv_r[row_perm] = np.arange(row_perm.shape[0])
        rows = inv_r[rows]
    cols = a.indices
    if col_perm is not None:
        col_perm = np.asarray(col_perm, dtype=np.int64)
        if col_perm.shape[0] != a.n_cols:
            raise ValueError(f"col_perm has {col_perm.shape[0]} entries "
                             f"for {a.n_cols} columns")
        inv_c = np.empty_like(col_perm)
        inv_c[col_perm] = np.arange(col_perm.shape[0])
        cols = inv_c[cols]
    return CSR.from_coo(a.n_rows, a.n_cols, rows.astype(np.int64),
                        cols.astype(np.int64), a.data.copy())


def permute_rows_cached(a: CSR, perm: np.ndarray) -> CSR:
    """Row-permuted view ``P·A``, memoized per (instance, perm digest).

    The SpMM-SpMM dispatch path row-permutes the first operand on every
    call with an active reorder; the memo makes that a one-off per
    (matrix, permutation) like every other pack in the system."""
    tag = hash((csr_content_digest(a), perm.tobytes()))
    memo = getattr(a, "_row_perm_memo", None)
    if memo is not None and memo[0] == tag:
        return memo[1]
    out = permute_csr(a, row_perm=perm)
    object.__setattr__(a, "_row_perm_memo", (tag, out))
    return out


def bandwidth(a: CSR) -> int:
    rows = np.repeat(np.arange(a.n_rows), np.diff(a.indptr))
    if rows.size == 0:
        return 0
    return int(np.abs(rows - a.indices).max())
