"""Heterogeneous multi-relation fusion — one dispatch for many SpMMs.

Hetero-GNN workloads (HGT/RGCN-style) run one small SpMM per relation;
each underfills the machine and re-pays the fixed dispatch cost.  This
module stacks the per-relation adjacencies **block-diagonally** into one
CSR, concatenates the dense per-relation operands to match, and routes
the whole thing through ``api.tile_fused_matmul`` — ONE Algorithm-1
inspection, one schedule-cache entry, one fused dispatch — then
un-stacks the per-relation outputs.  Every existing backend (pallas /
xla / unfused / sharded / serving) works unchanged: a block-diagonal
stack is just another sparse pattern to them, and ``spec.reorder`` /
``autotune`` / the custom_vjp compose for free.

Stacking geometry: relation ``r``'s adjacency ``a_r`` is ``(n_j_r,
n_i_r)``; it is placed on a **square pitch** ``S_r = max(n_j_r, n_i_r)``
on BOTH axes, so each block's row offset equals its column offset and
the stacked matrix is square.  That keeps the Algorithm-1 fusion
criterion effective (a fused row's dependencies sit near its own tile,
exactly as in the homogeneous case) and lets ``spec.reorder`` treat the
stack like any square pattern.  The pad rows/columns are empty —
vacuously fusable, never referenced — and cost nothing beyond index
space.

Math (GeMM-SpMM): with ``A = blockdiag(a_r)``, ``B = blockdiag(b_r)``
(dense, assembled per call — differentiable) and ``C = vstack(c_r)``,
``D = A·(B·C)`` has ``D[rows of block r] = a_r·(b_r·c_r)`` — the
per-relation products, computed jointly.  SpMM-SpMM stacks the op-1
CSRs block-diagonally on the same row pitch instead.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from ..sparse.formats import CSR, block_diag_csr, csr_content_digest
from . import api


@dataclasses.dataclass(frozen=True)
class HeteroStack:
    """A block-diagonal stack of relation adjacencies plus its geometry.

    ``pitches[r]`` is the square per-relation pitch ``max(n_j_r, n_i_r)``;
    ``offsets[r]`` the (row == column) start of block ``r``; ``row_sizes``
    / ``col_sizes`` the true (unpadded) per-relation shapes used to
    un-stack outputs and validate operands."""

    a: CSR
    offsets: tuple
    pitches: tuple
    row_sizes: tuple
    col_sizes: tuple

    @property
    def n_relations(self) -> int:
        return len(self.pitches)


_stack_cache: "collections.OrderedDict" = collections.OrderedDict()
_stack_lock = threading.Lock()
#: The stack caches are tiny (one entry per distinct relation *set*, not
#: per call) — bound them like the api caches but smaller.
STACK_CACHE_ENTRIES = 64


def _stack_cache_get(key):
    with _stack_lock:
        value = _stack_cache.get(key)
        if value is not None:
            _stack_cache.move_to_end(key)
        return value


def _stack_cache_put(key, value):
    with _stack_lock:
        _stack_cache[key] = value
        _stack_cache.move_to_end(key)
        while len(_stack_cache) > STACK_CACHE_ENTRIES:
            _stack_cache.popitem(last=False)


def clear_stack_cache() -> None:
    with _stack_lock:
        _stack_cache.clear()
    _dense_assembler.cache_clear()


def stack_adjacencies(adjs) -> HeteroStack:
    """Square-pitch block-diagonal stack of the relation adjacencies,
    memoized by the tuple of content digests (the stack is rebuilt only
    when the relation *set* changes — the serving amortization)."""
    adjs = list(adjs)
    if not adjs:
        raise ValueError("need at least one relation")
    key = ("adj",) + tuple(csr_content_digest(a) for a in adjs)
    stack = _stack_cache_get(key)
    if stack is not None:
        return stack
    pitches = tuple(max(a.n_rows, a.n_cols) for a in adjs)
    offsets = tuple(int(o) for o in
                    np.concatenate([[0], np.cumsum(pitches)[:-1]]))
    a = block_diag_csr(adjs, row_sizes=pitches, col_sizes=pitches)
    stack = HeteroStack(a=a, offsets=offsets, pitches=pitches,
                        row_sizes=tuple(m.n_rows for m in adjs),
                        col_sizes=tuple(m.n_cols for m in adjs))
    _stack_cache_put(key, stack)
    return stack


def _stack_op1(stack: HeteroStack, a1s) -> CSR:
    """Block-diagonal stack of the SpMM-SpMM op-1 CSRs: rows on the
    adjacency stack's pitch (so op-1 row ids line up with the stacked
    A's column ids), columns exact (C is a plain vstack).  Memoized like
    the adjacency stack."""
    key = ("op1", stack.pitches) + tuple(csr_content_digest(m) for m in a1s)
    a1 = _stack_cache_get(key)
    if a1 is not None:
        return a1
    a1 = block_diag_csr(a1s, row_sizes=stack.pitches,
                        col_sizes=[m.n_cols for m in a1s])
    _stack_cache_put(key, a1)
    return a1


@functools.lru_cache(maxsize=STACK_CACHE_ENTRIES)
def _dense_assembler(row_offsets: tuple, total_rows: int,
                     col_offsets: tuple, total_cols: int):
    """One jitted block-diagonal assembler per stack geometry.  Eager
    per-relation ``at[].set`` calls cost ~100x the copy itself in
    dispatch overhead on the serving hot path; under jit XLA fuses the
    whole assembly into one buffer init + N slice writes."""
    @jax.jit
    def assemble(*bs):
        dtype = jnp.result_type(*bs)
        out = jnp.zeros((total_rows, total_cols), dtype=dtype)
        for ro, co, b in zip(row_offsets, col_offsets, bs):
            out = jax.lax.dynamic_update_slice(out, b.astype(dtype),
                                               (ro, co))
        return out
    return assemble


@jax.jit
def _concat_rows(*cs):
    """Jitted row-concat of the per-relation dense operands.  One
    compiled call per shape set (jit's own cache) instead of an eager
    ``jnp.concatenate`` dispatch on every serving call."""
    return jnp.concatenate(cs, axis=0)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _unstack_rows(d, offsets: tuple, row_sizes: tuple):
    """Jitted un-stack of the fused output into per-relation blocks.
    Eager ``d[off:off+nj]`` slicing costs one dispatch per relation —
    the largest single overhead on the serving hot path; one jitted call
    returns all blocks at once."""
    return tuple(jax.lax.slice_in_dim(d, off, off + nj, axis=0)
                 for off, nj in zip(offsets, row_sizes))


def _block_diag_dense(stack: HeteroStack, bs) -> jax.Array:
    """Assemble the dense block-diagonal first operand ``B =
    blockdiag(b_r)`` on the stack's row pitch.  Pure functional writes
    into zeros — differentiable, so gradients flow back to each ``b_r``
    through the custom_vjp unchanged."""
    bs = [jnp.asarray(b) for b in bs]
    for size, b in zip(stack.col_sizes, bs):
        if b.shape[0] != size:
            raise ValueError(f"dense operand has {b.shape[0]} rows; the "
                             f"relation's adjacency has {size} columns")
    col_offsets = tuple(int(o) for o in np.concatenate(
        [[0], np.cumsum([b.shape[1] for b in bs])[:-1]]))
    total_cols = int(sum(b.shape[1] for b in bs))
    assemble = _dense_assembler(stack.offsets, int(sum(stack.pitches)),
                                col_offsets, total_cols)
    return assemble(*bs)


def hetero_fused_matmul(relations, *, backend: str = "auto",
                        spec: api.FusionSpec | None = None) -> list:
    """Per-relation ``D_r = a_r @ (b_or_a1_r @ c_r)`` as ONE fused dispatch.

    Args:
      relations: sequence of ``(a_r, b_or_a1_r, c_r)`` triples — the same
        operand shapes ``tile_fused_matmul`` takes, one per relation.
        All relations must be the same op pair (all-dense or all-CSR
        middle operands) and share ``c_col`` (the output feature width).
      backend, spec: forwarded verbatim to ``tile_fused_matmul`` — every
        knob (mesh, reorder, autotune, width_cap, ...) applies to the
        stacked problem as a whole.

    Returns the list of per-relation outputs ``[d_r]`` (``(n_j_r,
    c_col)`` each), exactly what the per-relation loop would produce.

    The stacked CSR(s) are memoized by relation-set content digest, so a
    serving loop over a fixed relation set re-stacks nothing and hits
    one schedule-cache entry; only the dense block-diagonal assembly
    (one scatter per relation) runs per call.
    """
    rels = [tuple(r) for r in relations]
    if not rels:
        raise ValueError("need at least one relation")
    if any(len(r) != 3 for r in rels):
        raise ValueError("each relation is an (a, b_or_a1, c) triple")
    sparse_flags = {isinstance(r[1], CSR) for r in rels}
    if len(sparse_flags) != 1:
        raise ValueError("relations mix dense and sparse first operands; "
                         "the stacked dispatch needs one op pair")
    b_is_sparse = sparse_flags.pop()
    c_cols = {int(np.shape(r[2])[1]) for r in rels}
    if len(c_cols) != 1:
        raise ValueError(f"relations disagree on c_col ({sorted(c_cols)}); "
                         f"stacked outputs share one feature width")
    stack = stack_adjacencies([r[0] for r in rels])
    if b_is_sparse:
        for (a_r, a1_r, c_r), n_i in zip(rels, stack.col_sizes):
            if a1_r.n_rows != n_i:
                raise ValueError(f"op-1 has {a1_r.n_rows} rows; the "
                                 f"adjacency has {n_i} columns")
            if np.shape(c_r)[0] != a1_r.n_cols:
                raise ValueError(f"c has {np.shape(c_r)[0]} rows; op-1 "
                                 f"has {a1_r.n_cols} columns")
        op1 = _stack_op1(stack, [r[1] for r in rels])
    else:
        op1 = _block_diag_dense(stack, [r[1] for r in rels])
        for (a_r, b_r, c_r) in rels:
            if np.shape(c_r)[0] != np.shape(b_r)[1]:
                raise ValueError(f"c has {np.shape(c_r)[0]} rows; b has "
                                 f"{np.shape(b_r)[1]} columns")
    c_cat = _concat_rows(*[jnp.asarray(r[2]) for r in rels])
    d = api.tile_fused_matmul(stack.a, op1, c_cat, backend=backend,
                              spec=spec)
    return list(_unstack_rows(d, stack.offsets, stack.row_sizes))


def hetero_loop_matmul(relations, *, backend: str = "auto",
                       spec: api.FusionSpec | None = None) -> list:
    """The per-relation baseline the fused stack replaces: one
    ``tile_fused_matmul`` dispatch per relation (N inspections, N cache
    entries, N launches).  Kept as the parity oracle and the bench
    baseline."""
    return [api.tile_fused_matmul(a, b_or_a1, c, backend=backend, spec=spec)
            for a, b_or_a1, c in relations]
