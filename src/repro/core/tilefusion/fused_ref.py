"""Schedule-walking numpy oracle.

Executes the fused schedule tile by tile *in schedule order* and asserts the
central correctness invariant: every D1 row read by a fused second-op
iteration was produced earlier in the SAME tile (wavefront 0) or in any
wavefront-0 tile (wavefront 1, after the barrier).  This is the executable
statement of the paper's "no synchronization inside a wavefront" guarantee.
"""
from __future__ import annotations

import numpy as np

from ..sparse.formats import CSR
from .scheduler import Schedule


def run_gemm_spmm(a: CSR, b: np.ndarray, c: np.ndarray, sched: Schedule,
                  check: bool = True) -> np.ndarray:
    """D = A @ (B @ C) executed per the fused schedule."""
    n_i, n_j = sched.n_i, sched.n_j
    c_col = c.shape[1]
    d1 = np.zeros((n_i, c_col), dtype=np.float64)
    d1_ready = np.zeros(n_i, dtype=bool)
    d = np.zeros((n_j, c_col), dtype=np.float64)

    # ---- wavefront 0 ----
    for tl in sched.wavefronts[0]:
        local_ready = np.zeros(n_i, dtype=bool)
        d1[tl.i_start:tl.i_end] = b[tl.i_start:tl.i_end] @ c
        local_ready[tl.i_start:tl.i_end] = True
        for j in tl.j_rows:
            cols, vals = a.row(int(j))
            if check:
                assert local_ready[cols].all(), (
                    f"tile [{tl.i_start},{tl.i_end}) fused row {j} reads D1 "
                    f"rows outside the tile — scheduler bug")
            d[j] = vals @ d1[cols]
        d1_ready[tl.i_start:tl.i_end] = True
    if check:
        assert d1_ready.all(), "wavefront 0 did not produce all of D1"

    # ---- barrier; wavefront 1 ----
    for tl in sched.wavefronts[1]:
        for j in tl.j_rows:
            cols, vals = a.row(int(j))
            d[j] = vals @ d1[cols]
    return d


def run_spmm_spmm(a: CSR, a1: CSR, c: np.ndarray, sched: Schedule,
                  check: bool = True) -> np.ndarray:
    """D = A @ (A1 @ C) executed per the fused schedule (both ops SpMM)."""
    n_i, n_j = sched.n_i, sched.n_j
    c_col = c.shape[1]
    d1 = np.zeros((n_i, c_col), dtype=np.float64)
    d = np.zeros((n_j, c_col), dtype=np.float64)
    d1_ready = np.zeros(n_i, dtype=bool)

    for tl in sched.wavefronts[0]:
        for i in range(tl.i_start, tl.i_end):
            cols, vals = a1.row(i)
            d1[i] = vals @ c[cols]
        for j in tl.j_rows:
            cols, vals = a.row(int(j))
            if check:
                assert ((cols >= tl.i_start) & (cols < tl.i_end)).all(), (
                    f"fused row {j} escapes tile [{tl.i_start},{tl.i_end})")
            d[j] = vals @ d1[cols]
        d1_ready[tl.i_start:tl.i_end] = True
    if check:
        assert d1_ready.all()

    for tl in sched.wavefronts[1]:
        for j in tl.j_rows:
            cols, vals = a.row(int(j))
            d[j] = vals @ d1[cols]
    return d


def unfused_gemm_spmm(a: CSR, b: np.ndarray, c: np.ndarray) -> np.ndarray:
    return a.to_dense() @ (b @ c)


def unfused_spmm_spmm(a: CSR, a1: CSR, c: np.ndarray) -> np.ndarray:
    return a.to_dense() @ (a1.to_dense() @ c)
