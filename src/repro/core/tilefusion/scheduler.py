"""Tile-fusion scheduler — Algorithm 1 of the paper.

Builds a two-wavefront schedule of fused tiles from the sparsity pattern of
``A`` in ``D = A(BC)``:

  Step 1 (coarse tile fusion): uniform coarse tiles of ``t`` consecutive
    first-op iterations; a second-op iteration ``j`` is fused into tile ``v``
    iff *all* of its dependencies (nonzero column indices of row ``j`` of
    ``A``) fall inside tile ``v``'s contiguous range.  Unfused iterations go
    to wavefront 1 and are balanced.

  Step 2 (fused tile splitting): tiles whose Eq-3 data-movement cost exceeds
    ``cache_size`` are split recursively (factor 2) until they fit.  A fused
    ``j`` whose dependencies span both halves of a split can no longer run
    synchronization-free in wavefront 0 and is demoted to wavefront 1 (the
    paper's locality constraint takes precedence over its fused ratio).

The schedule is computed once per sparsity pattern (numpy, host side) and
reused across steps — the amortization argument of paper §4.2.3.

The inspector itself is O(nnz) vectorized: the fusion test ``all deps of
row j in [i_start, i_end)`` is equivalent to ``row_min[j] >= i_start and
row_max[j] < i_end`` where the per-row column extents come from one
``ufunc.reduceat`` pass (``CSR.row_extents``, memoized per matrix).  Step 1
classifies every candidate row in one shot instead of re-scanning CSR rows
per tile; step 2's recursive split reuses the same extents.  The original
row-at-a-time implementation is retained in ``reference.py`` for parity
tests and the inspector benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from ..sparse.formats import CSR, csr_gather_rows
from .cost_model import tile_cost_elements, tile_costs_batch


@dataclasses.dataclass
class Tile:
    """One fused tile: first-op rows [i_start, i_end) + fused second-op rows."""

    i_start: int
    i_end: int
    j_rows: np.ndarray  # int32, sorted

    @property
    def n_i(self) -> int:
        return self.i_end - self.i_start

    @property
    def n_j(self) -> int:
        return int(self.j_rows.size)


@dataclasses.dataclass
class Schedule:
    wavefronts: List[List[Tile]]  # exactly two
    n_i: int                      # |I|  (first-op iterations)
    n_j: int                      # |J|  (second-op iterations)
    t: int                        # coarse tile size chosen in step 1

    @property
    def fused_ratio(self) -> float:
        """Equation 2: fused second-op iterations over total iterations."""
        fused = sum(tl.n_j for tl in self.wavefronts[0])
        return fused / max(self.n_i + self.n_j, 1)

    def validate(self) -> None:
        """Structural invariants (used by tests)."""
        assert len(self.wavefronts) == 2
        i_seen = np.zeros(self.n_i, dtype=bool)
        for tl in self.wavefronts[0]:
            assert 0 <= tl.i_start <= tl.i_end <= self.n_i
            assert not i_seen[tl.i_start:tl.i_end].any(), "I ranges overlap"
            i_seen[tl.i_start:tl.i_end] = True
        assert i_seen.all(), "I iterations not fully covered by wavefront 0"
        j_seen = np.zeros(self.n_j, dtype=np.int32)
        for wf in self.wavefronts:
            for tl in wf:
                np.add.at(j_seen, tl.j_rows, 1)
        assert (j_seen == 1).all(), "J iterations not covered exactly once"


def _fused_mask(a: CSR, i_start: int, i_end: int, j_candidates: np.ndarray) -> np.ndarray:
    """True for candidate rows whose every dependency lies in [i_start, i_end).

    O(len(j_candidates)) after the matrix's one-time extents pass; empty
    rows are vacuously fusable (extents sentinel (n_cols, -1))."""
    row_min, row_max = a.row_extents()
    j = np.asarray(j_candidates)
    return (row_min[j] >= i_start) & (row_max[j] < i_end)


def row_extents_for(a: CSR, rows: np.ndarray):
    """Per-row (min, max) column extents for just ``rows``.

    The dirty-row slice of the incremental inspector: O(nnz of the given
    rows) instead of the full-matrix pass of ``CSR.row_extents`` — on a
    request whose pattern differs from the resident one in a few rows,
    this is what keeps the patch sublinear in the matrix.  Empty rows get
    the same ``(n_cols, -1)`` vacuous-containment sentinel."""
    rows = np.asarray(rows, dtype=np.int64)
    flat, lens = csr_gather_rows(a, rows)
    rmin = np.full(rows.shape[0], a.n_cols, dtype=np.int64)
    rmax = np.full(rows.shape[0], -1, dtype=np.int64)
    nonempty = lens > 0
    if nonempty.any():
        cum = np.concatenate([[0], np.cumsum(lens)])
        cols = a.indices[flat].astype(np.int64)
        starts = cum[:-1][nonempty]
        rmin[nonempty] = np.minimum.reduceat(cols, starts)
        rmax[nonempty] = np.maximum.reduceat(cols, starts)
    return rmin, rmax


def _split_tile(a: CSR, tile: Tile, b_col: int, c_col: int, b_is_sparse: bool,
                cache_size: float, demoted: list,
                cost: float | None = None,
                width_cap: int | None = None) -> List[Tile]:
    """Step-2 recursive split (factor 2) until the Eq-3 cost fits cache_size.

    ``cost`` lets the caller pass the tile's already-batched Eq-3 cost so
    the common all-tiles-fit case never re-derives it; recursive children
    compute their own."""
    if cost is None:
        cost = tile_cost_elements(a, tile.i_start, tile.i_end, tile.j_rows,
                                  b_col, c_col, b_is_sparse,
                                  width_cap=width_cap)
    if cost <= cache_size or tile.n_i <= 1:
        if cost > cache_size and tile.n_j > 0 and tile.n_i <= 1:
            # cannot shrink the producer side further; shed consumers instead
            keep = tile.j_rows[: max(tile.n_j // 2, 0)]
            demoted.append(tile.j_rows[keep.shape[0]:])
            return [Tile(tile.i_start, tile.i_end, keep)]
        return [tile]
    mid = tile.i_start + tile.n_i // 2
    mask_lo = _fused_mask(a, tile.i_start, mid, tile.j_rows)
    mask_hi = _fused_mask(a, mid, tile.i_end, tile.j_rows)
    j_lo = tile.j_rows[mask_lo]
    j_hi = tile.j_rows[mask_hi & ~mask_lo]
    spanning = tile.j_rows[~(mask_lo | mask_hi)]
    if spanning.size:
        demoted.append(spanning)
    lo = Tile(tile.i_start, mid, j_lo)
    hi = Tile(mid, tile.i_end, j_hi)
    return (_split_tile(a, lo, b_col, c_col, b_is_sparse, cache_size, demoted,
                        width_cap=width_cap)
            + _split_tile(a, hi, b_col, c_col, b_is_sparse, cache_size,
                          demoted, width_cap=width_cap))


def _split_wf1_tile(a: CSR, j_rows: np.ndarray, b_col: int, c_col: int,
                    b_is_sparse: bool, cache_size: float,
                    cost: float | None = None,
                    width_cap: int | None = None) -> List[Tile]:
    if cost is None:
        cost = tile_cost_elements(a, 0, 0, j_rows, b_col, c_col, b_is_sparse,
                                  width_cap=width_cap)
    if cost <= cache_size or j_rows.size <= 1:
        return [Tile(0, 0, j_rows)]
    mid = j_rows.size // 2
    return (_split_wf1_tile(a, j_rows[:mid], b_col, c_col, b_is_sparse,
                            cache_size, width_cap=width_cap)
            + _split_wf1_tile(a, j_rows[mid:], b_col, c_col, b_is_sparse,
                              cache_size, width_cap=width_cap))


def _balance(j_all: np.ndarray, t: int, p: int) -> List[np.ndarray]:
    """Evenly distribute wavefront-1 iterations (line 15 of Algorithm 1)."""
    if j_all.size == 0:
        return []
    n_tiles = max(p, -(-j_all.size // max(t, 1)))
    n_tiles = min(n_tiles, j_all.size)
    return [chunk.astype(np.int32) for chunk in np.array_split(np.sort(j_all), n_tiles)]


def _step1(a: CSR, t: int, n_i: int, n_j: int):
    """Coarse tile fusion at tile size t (lines 5-14 of Algorithm 1).

    Fully vectorized: every candidate row j < min(n_i, n_j) belongs to
    coarse tile v = j // t, and the fusion test is one extents comparison
    over all candidates at once; rows are then grouped per tile by
    splitting the (already tile-sorted) index vector at tile boundaries.
    """
    tile_lo = np.arange(0, n_i, t, dtype=np.int64)
    tile_hi = np.minimum(tile_lo + t, n_i)
    j_all = np.arange(min(n_i, n_j), dtype=np.int64)
    row_min, row_max = a.row_extents()
    v = j_all // t
    fused = (row_min[j_all] >= tile_lo[v]) & (row_max[j_all] < tile_hi[v])
    f_j = j_all[fused].astype(np.int32)
    u_j = j_all[~fused].astype(np.int32)
    f_parts = np.split(f_j, np.searchsorted(f_j, tile_lo[1:]))
    u_parts = np.split(u_j, np.searchsorted(u_j, tile_lo[1:]))
    wf0 = [Tile(int(lo), int(hi), fp)
           for lo, hi, fp in zip(tile_lo, tile_hi, f_parts)]
    unfused: List[np.ndarray] = [up for up in u_parts if up.size]
    if n_j > n_i:  # second op has more rows than first op produces tiles for
        unfused.append(np.arange(n_i, n_j, dtype=np.int32))
    return wf0, unfused


def build_schedule(
    a: CSR,
    b_col: int,
    c_col: int,
    p: int = 8,
    cache_size: float = 600_000.0,   # elements; see cost_model for byte budgets
    ct_size: int = 2048,
    b_is_sparse: bool = False,
    uniform_split: bool = False,
    width_cap: int | None = None,
) -> Schedule:
    """Algorithm 1.  ``a`` is the sparse matrix of the *second* operation
    (its pattern defines the iteration DAG: row j of op2 depends on D1 rows
    given by its nonzero columns).  For GeMM-SpMM |I| = a.n_cols (rows of
    D1 = BC), for SpMM-SpMM (D = A(AC)) |I| = |J| = n.

    ``uniform_split=True`` is the TPU adaptation of step 2 (DESIGN.md §2):
    instead of recursively splitting individual oversized tiles, the tile
    size is halved *globally* until every tile's cost fits — all tiles share
    one size, so the fused code is a single batched matmul with zero padding
    waste (and maps 1:1 onto the Pallas kernel's uniform grid).

    ``width_cap`` (sparse-B only) makes the Eq-3 cost price the op-1 operand
    as capped-width hybrid-ELL traffic (padded body + spill lanes) instead of
    raw nonzeros — the width the executors actually stream.  ``None`` keeps
    the paper's idealized charge (and the pre-cap schedules bit-for-bit).
    """
    n_i = a.n_cols
    n_j = a.n_rows

    # ---- Step 1: coarse tile fusion (lines 3-15) ----
    if -(-n_i // ct_size) >= p:
        t = ct_size
    else:
        t = max(-(-n_i // p), 1)

    def _wf0_costs(wf0):
        return tile_costs_batch(a, [tl.i_start for tl in wf0],
                                [tl.i_end for tl in wf0],
                                [tl.j_rows for tl in wf0],
                                b_col, c_col, b_is_sparse,
                                width_cap=width_cap)

    if uniform_split:
        # ---- Step 2 (uniform variant): halve t globally until it fits ----
        while True:
            wf0, unfused = _step1(a, t, n_i, n_j)
            costs = _wf0_costs(wf0)
            worst = float(costs.max()) if costs.size else 0.0
            if worst <= cache_size or t <= 64:
                break
            t //= 2
        split_wf0, demoted = wf0, []
    else:
        wf0, unfused = _step1(a, t, n_i, n_j)
        # ---- Step 2: fused tile splitting (lines 16-23); entry costs are
        # batched so only genuinely oversized tiles pay the recursion ----
        demoted = []
        split_wf0 = []
        for tl, cost in zip(wf0, _wf0_costs(wf0)):
            split_wf0.extend(_split_tile(a, tl, b_col, c_col, b_is_sparse,
                                         cache_size, demoted, cost=cost,
                                         width_cap=width_cap))

    j_wf1 = np.concatenate(unfused + demoted) if (unfused or demoted) \
        else np.zeros(0, np.int32)
    wf1: List[Tile] = []
    chunks = _balance(j_wf1, t, p)
    chunk_costs = tile_costs_batch(a, np.zeros(len(chunks), np.int64),
                                   np.zeros(len(chunks), np.int64),
                                   chunks, b_col, c_col, b_is_sparse,
                                   width_cap=width_cap)
    for chunk, cost in zip(chunks, chunk_costs):
        wf1.extend(_split_wf1_tile(a, chunk, b_col, c_col, b_is_sparse,
                                   cache_size, cost=cost,
                                   width_cap=width_cap))

    sched = Schedule(wavefronts=[split_wf0, wf1], n_i=n_i, n_j=n_j, t=t)
    sched.validate()
    return sched


def balanced_contiguous_partition(costs: np.ndarray,
                                  n_parts: int) -> np.ndarray:
    """Split a tile sequence into ``n_parts`` contiguous groups minimizing
    the max group Eq-3 cost (the shard balance term of the sharded
    dispatch: every shard gets comparable fused-tile work, and contiguity
    preserves the 1-D row-block partition of D1).

    Binary search on the bottleneck cost over the prefix sums; returns
    ``(n_parts + 1,)`` tile-index bounds (trailing groups may be empty when
    there are fewer tiles than parts).
    """
    costs = np.asarray(costs, dtype=np.float64)
    n = costs.shape[0]
    bounds = np.zeros(n_parts + 1, dtype=np.int64)
    if n == 0 or n_parts <= 0:
        return bounds
    prefix = np.concatenate([[0.0], np.cumsum(costs)])

    def cuts_for(bottleneck: float) -> np.ndarray:
        """Greedy left-to-right packing at a given bottleneck; may use
        fewer than n_parts groups (never more than n)."""
        cut = [0]
        while cut[-1] < n:
            # furthest end with group sum <= bottleneck, at least one tile
            end = int(np.searchsorted(prefix, prefix[cut[-1]] + bottleneck,
                                      side="right")) - 1
            cut.append(max(end, cut[-1] + 1))
        return np.asarray(cut, dtype=np.int64)

    lo = float(costs.max())
    hi = float(prefix[-1])
    for _ in range(48):
        mid = 0.5 * (lo + hi)
        if cuts_for(mid).shape[0] - 1 <= n_parts:
            hi = mid
        else:
            lo = mid
    cut = cuts_for(hi)
    k = cut.shape[0] - 1              # groups actually used (<= n_parts)
    bounds[: k + 1] = cut
    bounds[k + 1:] = n                # trailing empty shards
    return bounds


#: Layouts a mesh's axes can be resolved into (plus "auto" upstream).
MESH_LAYOUTS = ("1d", "1.5d", "2.5d")


def resolve_mesh_layout(mesh_shape, layout: str) -> tuple:
    """THE layout rule, defined once: how many row shards × column
    replicas × depth replicas a mesh shape yields under a layout.

    Returns ``(n_row, n_repl, n_depth)``.  ``"1d"`` flattens every mesh
    axis into row-block shards (a 2-D mesh in C order, matching
    ``PartitionSpec((ax0, ax1))`` block order); ``"1.5d"`` partitions tiles
    over the *leading* axis only and leaves the trailing axes as column
    replicas of the dense operand; ``"2.5d"`` keeps axis 0 for row blocks,
    axis 1 for column replicas, and folds the remaining axes into a depth
    dimension that replicates the wavefront-0 compute and splits the
    wavefront-1 halo work (Bharadwaj et al.'s replication ladder).  A mesh
    without enough axes degenerates down the ladder ("2.5d" → the "1.5d"
    resolution → "1d").  Every consumer (the api dispatch, the partitioner
    below, the shard_map axis split in ``models/sharding``) derives its
    split from this function so the layers can never disagree."""
    if layout not in MESH_LAYOUTS:
        raise ValueError(f"layout={layout!r}; expected one of "
                         f"{MESH_LAYOUTS}")
    shape = tuple(int(x) for x in np.atleast_1d(mesh_shape))
    total = 1
    for x in shape:
        total *= x
    if layout == "2.5d" and len(shape) >= 3:
        depth = 1
        for x in shape[2:]:
            depth *= x
        if depth > 1 and shape[1] > 1:
            return shape[0], shape[1], depth
        if depth > 1 and shape[1] == 1:
            # nothing to column-replicate; fold depth into the replica slot
            return shape[0], depth, 1
    if layout in ("1.5d", "2.5d") and len(shape) >= 2 and total > shape[0]:
        return shape[0], total // shape[0], 1
    return total, 1, 1


def balanced_mesh_partition(costs: np.ndarray, mesh_shape,
                            layout: str = "1d") -> tuple:
    """Mesh-aware front end of ``balanced_contiguous_partition``: resolve a
    mesh shape + layout into (row-axis tile bounds, n_row, n_repl,
    n_depth).  Tiles are shared within a replica group (and replicated
    across depth), so only the row axis enters the balance."""
    n_row, n_repl, n_depth = resolve_mesh_layout(mesh_shape, layout)
    return balanced_contiguous_partition(costs, n_row), n_row, n_repl, n_depth


def fused_compute_ratio(a: CSR, ct_size: int = 2048) -> float:
    """Figure 1's metric: fraction of second-op *computation* (nonzeros) whose
    dependencies are contained in coarse tiles of size ct_size.

    One vectorized pass: candidate rows j < min(n_cols, n_rows), tile
    range [ (j//ct)·ct, min((j//ct+1)·ct, n_cols) ), extents containment,
    then a masked sum of per-row nonzero counts."""
    row_min, row_max = a.row_extents()
    j = np.arange(min(a.n_cols, a.n_rows), dtype=np.int64)
    i0 = (j // ct_size) * ct_size
    i1 = np.minimum(i0 + ct_size, a.n_cols)
    m = (row_min[j] >= i0) & (row_max[j] < i1)
    counts = (a.indptr[1:] - a.indptr[:-1]).astype(np.int64)
    fused_nnz = int(counts[j[m]].sum())
    return fused_nnz / max(a.nnz, 1)
