"""Dynamic-pattern serving tier — bucketed schedule reuse + incremental
inspection for sampled-subgraph request streams.

The inspector cache in ``api.py`` is content-keyed: production GNN serving
streams neighbor-sampled subgraphs where *every* request is a new pattern,
so Algorithm 1 runs O(nnz) on the hot path and the amortization argument
(paper §4.2.3, Fig. 10) never pays off.  ``ServingTier`` makes schedules
reusable across *similar* patterns, not just identical ones:

  1. **Bucketed canonicalization.**  Requests are padded (empty trailing
     rows/columns, a no-op in every executor) into a small set of
     ``(rows, cols, width_cap)`` shape buckets — pow2-quantized dims, so
     one cached ``DeviceSchedule`` and one compiled executor (static
     shapes!) serve a whole bucket.  The choice is priced, not assumed:
     ``cost_model.serving_bucket_price`` weighs the Eq-3 padded-traffic
     overhead each call pays against the amortized inspection a bucket
     saves, and requests where padding costs more keep their exact shape.

  2. **Incremental inspection.**  When a request differs from the
     bucket's resident pattern in few rows (``csr_dirty_rows``, a
     vectorized per-row diff), ``incremental_update`` patches the
     resident schedule instead of re-running Algorithm 1: the fusion
     test (via ``scheduler.row_extents_for``, O(dirty nnz)) and the ELL
     repack run only for dirty tiles; rows entering wavefront 1 land in
     no-op pad slots reserved by ``schedule.pad_device_schedule`` at
     bucket build, so no array changes shape and nothing recompiles.
     The loop-reference semantics live in ``reference.py``; patched
     schedules are parity-pinned against ``fused_ref`` (including its
     ``check=True`` wavefront-invariant walk) in the tests.  A patched
     schedule keeps the resident tiling, so it can be *less* optimal
     than a fresh inspection — that is the priced tradeoff: patch cost
     is O(dirty), full inspection O(nnz).

  3. **Cache integration.**  Entries are published under the bucket key
     (``api.get_schedule(bucket=...)`` / ``api.store_bucket_schedule``):
     N patterns in one bucket occupy exactly one cache slot, hits and
     misses stay observable via ``schedule_cache_stats()`` (which also
     counts ``bucket_entries`` and ``incremental_patches``), and the LRU
     bound never thrashes on pattern streams.

The request-batching front end (stacking same-bucket requests into one
dispatch) lives in ``launch/serve.py::SubgraphFrontEnd``; benchmarks in
``benchmarks/serving_bench.py``.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from ..sparse.formats import (CSR, csr_content_digest, csr_gather_rows,
                              ell_slot_coords)
from . import api, cost_model, fused_ops
from .schedule import _ell_arrays, pad_device_schedule
from .scheduler import Schedule, Tile, row_extents_for, tile_costs_batch


# --------------------------------------------------------------------------
# Pattern canonicalization
# --------------------------------------------------------------------------
def pad_csr(a: CSR, n_rows: int, n_cols: int) -> CSR:
    """Embed ``a`` in the top-left of an ``(n_rows, n_cols)`` pattern.

    Appended rows are empty — vacuously fusable under the Algorithm-1
    extents sentinel and a zero row of D in every executor — and appended
    columns are simply never referenced, so the padded product's leading
    ``a.n_rows`` rows equal the unpadded product exactly."""
    if n_rows < a.n_rows or n_cols < a.n_cols:
        raise ValueError(f"cannot pad ({a.n_rows}, {a.n_cols}) down to "
                         f"({n_rows}, {n_cols})")
    if (n_rows, n_cols) == (a.n_rows, a.n_cols):
        return a
    indptr = np.concatenate(
        [a.indptr, np.full(n_rows - a.n_rows, a.indptr[-1], a.indptr.dtype)])
    return CSR(n_rows, n_cols, indptr.astype(np.int32), a.indices, a.data)


def csr_dirty_rows(old: CSR, new: CSR) -> np.ndarray | None:
    """Rows whose pattern or values differ between two same-shape CSRs
    (None when the shapes differ — no row-level diff exists).

    Vectorized: rows with different nonzero counts are dirty outright;
    equal-count rows are compared entry-wise through one flat gather per
    matrix, robust to the row-start offsets shifting between the two."""
    if (old.n_rows, old.n_cols) != (new.n_rows, new.n_cols):
        return None
    lo = np.diff(old.indptr)
    ln = np.diff(new.indptr)
    dirty = lo != ln
    same = np.nonzero(~dirty)[0]
    if same.size:
        fo, lens = csr_gather_rows(old, same)
        fn, _ = csr_gather_rows(new, same)
        diff = (old.indices[fo] != new.indices[fn]) \
            | (old.data[fo] != new.data[fn])
        if diff.any():
            row_rep = np.repeat(same, lens)
            dirty[np.unique(row_rep[diff])] = True
    return np.nonzero(dirty)[0].astype(np.int64)


# --------------------------------------------------------------------------
# Incremental inspector
# --------------------------------------------------------------------------
def incremental_update(a_old: CSR, entry: api.ScheduleEntry, a_new: CSR,
                       dirty: np.ndarray, *,
                       cache_size: float) -> api.ScheduleEntry | None:
    """Patch ``entry`` (inspected for ``a_old``) to serve ``a_new`` when
    only ``dirty`` rows differ; None means "rebuild instead".

    The patch re-runs exactly the per-row work Algorithm 1 would redo:
    the fusion test for the dirty rows (one ``row_extents_for`` pass over
    their nonzeros), the tile-local ELL repack for the wavefront-0 tiles
    they touch, and slot surgery in the wavefront-1 arrays — freed slots
    (row index ``n_j``, zero entries) absorb leaving rows, reserved pad
    slots absorb entering ones, so every array keeps its shape and the
    compiled executors keep their cache.  Bails to None (full rebuild)
    when capacity runs out (more entering rows than free slots, a row
    wider than the packed width) or a patched tile's Eq-3 cost exceeds
    ``cache_size`` — the same budget step 2 enforces."""
    t0 = time.perf_counter()
    ds = entry.dsched
    sched = entry.sched
    if entry.shard is not None or entry.mesh_key is not None:
        return None
    if entry.reorder_perm is not None:
        # a baked permutation renumbers every row the dirty diff names —
        # patch-by-position would corrupt it silently (bucket entries
        # never carry one: get_schedule rejects bucket= + reorder=)
        return None
    if not fused_ops._is_uniform(ds):
        return None
    n_i, n_j, t = sched.n_i, sched.n_j, sched.t
    if (a_new.n_rows, a_new.n_cols) != (n_j, n_i):
        return None
    dirty = np.unique(np.asarray(dirty, dtype=np.int64))
    if dirty.size == 0:
        return entry
    wf0, wf1 = sched.wavefronts

    # ---- fusion test, dirty rows only (Algorithm 1 line 8, sliced) ----
    cand = dirty < min(n_i, n_j)
    rmin, rmax = row_extents_for(a_new, dirty)
    v = dirty // t                      # uniform grid: tile of row j
    tile_lo = v * t
    tile_hi = np.minimum(tile_lo + t, n_i)
    fusable = cand & (rmin >= tile_lo) & (rmax < tile_hi)

    old_fused = np.zeros(n_j, dtype=bool)
    if wf0:
        f_all = np.concatenate([tl.j_rows for tl in wf0])
        if f_all.size:
            old_fused[f_all] = True
    dirty_mask = np.zeros(n_j, dtype=bool)
    dirty_mask[dirty] = True

    # ---- host wavefront 0: rewrite only the affected tiles ----
    aff = np.unique(v[(old_fused[dirty] | fusable) & cand])
    wf0_new = list(wf0)
    for tv in aff:
        tl = wf0[int(tv)]
        keep = tl.j_rows[~dirty_mask[tl.j_rows]]
        add = dirty[fusable & (v == tv)]
        j_new = np.sort(np.concatenate(
            [keep.astype(np.int64), add])).astype(np.int32)
        wf0_new[int(tv)] = Tile(tl.i_start, tl.i_end, j_new)
    if aff.size:
        costs = tile_costs_batch(
            a_new, [wf0_new[int(tv)].i_start for tv in aff],
            [wf0_new[int(tv)].i_end for tv in aff],
            [wf0_new[int(tv)].j_rows for tv in aff],
            entry.b_col, entry.c_col, entry.b_is_sparse,
            width_cap=entry.width_cap)
        if costs.size and float(costs.max()) > cache_size:
            return None                 # patched tile busts the budget

    # ---- host wavefront 1: drop dirty rows, append the entering ones ----
    entering = np.sort(dirty[~fusable]).astype(np.int32)
    wf1_new = []
    for tl in wf1:
        m = dirty_mask[tl.j_rows]
        wf1_new.append(Tile(0, 0, tl.j_rows[~m]) if m.any() else tl)
    if entering.size:
        wf1_new.append(Tile(0, 0, entering))
    wf1_new = [tl for tl in wf1_new if tl.j_rows.size]
    new_sched = Schedule(wavefronts=[wf0_new, wf1_new], n_i=n_i, n_j=n_j,
                         t=t)
    new_sched.validate()

    # ---- device wavefront 0: repack only the affected tiles ----
    j_rows0, cols0, vals0 = ds.j_rows0, ds.ell_cols0, ds.ell_vals0
    if aff.size:
        j0_max = ds.j_rows0.shape[1]
        w0 = ds.ell_cols0.shape[2]
        lists = [wf0_new[int(tv)].j_rows for tv in aff]
        if max(jr.size for jr in lists) > j0_max:
            return None                 # more fused rows than slots
        starts = np.asarray([wf0[int(tv)].i_start for tv in aff], np.int64)
        sub_jr, sub_c, sub_v, _ = _ell_arrays(
            a_new, lists, j0_max, pad_row=n_j, local_start=starts)
        ws = sub_c.shape[2]
        if ws > w0:
            return None                 # a fused row outgrew the ELL width
        j_rows0 = ds.j_rows0.copy()
        cols0 = ds.ell_cols0.copy()
        vals0 = ds.ell_vals0.copy()
        j_rows0[aff] = sub_jr
        cols0[aff] = 0
        vals0[aff] = 0.0
        cols0[aff, :, :ws] = sub_c
        vals0[aff, :, :ws] = sub_v

    # ---- device wavefront 1: slot surgery on the flat view ----
    t1, j1 = ds.j_rows1.shape
    w1 = ds.ell_cols1.shape[2] if ds.ell_cols1.size else 1
    jr1 = ds.j_rows1.reshape(-1).copy()
    c1 = ds.ell_cols1.reshape(-1, w1).copy()
    v1 = ds.ell_vals1.reshape(-1, w1).copy()
    sr = ds.spill_rows1.copy()
    sc = ds.spill_cols1.copy()
    sv = ds.spill_vals1.copy()
    rmask = np.zeros(n_j + 1, dtype=bool)   # index n_j = pad slot, clean
    rmask[dirty] = True
    slot_dirty = rmask[jr1]
    jr1[slot_dirty] = n_j
    c1[slot_dirty] = 0
    v1[slot_dirty] = 0.0
    if sr.size:
        sp_dirty = rmask[sr]
        sr[sp_dirty] = 0
        sc[sp_dirty] = 0
        sv[sp_dirty] = 0.0              # val-0 lanes are scatter-add no-ops
    if entering.size:
        free = np.nonzero(jr1 == n_j)[0]
        if entering.size > free.size:
            return None                 # headroom exhausted
        slots = free[: entering.size]
        jr1[slots] = entering
        flat, lens = csr_gather_rows(a_new, entering)
        if flat.size:
            row_rep, w_idx = ell_slot_coords(lens)
            body = w_idx < w1
            c1[slots[row_rep[body]], w_idx[body]] = a_new.indices[flat[body]]
            v1[slots[row_rep[body]], w_idx[body]] = a_new.data[flat[body]]
            sp = ~body
            n_sp = int(sp.sum())
            if n_sp:
                # explicit-zero lanes read as free; overwriting one only
                # replaces a zero contribution, so this stays sound
                free_sp = np.nonzero(sv == 0.0)[0]
                if n_sp > free_sp.size:
                    return None         # spill headroom exhausted
                idx = free_sp[:n_sp]
                sr[idx] = entering[row_rep[sp]]
                sc[idx] = a_new.indices[flat[sp]]
                sv[idx] = a_new.data[flat[sp]]

    ds_new = dataclasses.replace(
        ds, j_rows0=j_rows0, ell_cols0=cols0, ell_vals0=vals0,
        j_rows1=jr1.reshape(t1, j1), ell_cols1=c1.reshape(t1, j1, w1),
        ell_vals1=v1.reshape(t1, j1, w1), spill_rows1=sr, spill_cols1=sc,
        spill_vals1=sv)
    tm = ds_new.hbm_traffic_model(entry.b_col, entry.c_col)
    tm["packed_ell_bytes"] = api._packed_ell_bytes(a_new, ds_new,
                                                   entry.b_is_sparse)
    return dataclasses.replace(
        entry, sched=new_sched, dsched=ds_new, traffic_model=tm, hits=0,
        inspector_s=time.perf_counter() - t0,
        content_digest=csr_content_digest(a_new))


# --------------------------------------------------------------------------
# The tier
# --------------------------------------------------------------------------
@dataclasses.dataclass
class _Resident:
    """What a bucket currently serves: the padded pattern, its digest, and
    the (headroom-padded or patched) cache entry."""

    a: CSR
    digest: bytes
    entry: api.ScheduleEntry


class ServingTier:
    """Bucketed + incremental front of ``tile_fused_matmul`` for request
    streams (one instance per served (b_col, c_col) model head).

    ``matmul(a, b_or_a1, c)`` pads the request into its shape bucket,
    resolves the bucket's schedule (exact digest hit → cached entry;
    ≤ ``max_dirty_frac`` rows changed → incremental patch; otherwise a
    full rebuild with wavefront-1 headroom for future patches), and
    dispatches through the ``api`` seam with the ``bucket=`` cache knob.
    ``stats``/``hit_rate()`` report how often the O(nnz) inspector was
    avoided — the serving-bench headline number."""

    def __init__(self, *, b_col: int, c_col: int, b_is_sparse: bool = False,
                 p: int = 8, cache_size: float = 600_000.0,
                 ct_size: int = 2048, width_cap: int | str | None = "auto",
                 backend: str = "auto", max_dirty_frac: float = 0.05,
                 expected_reuse: float = 8.0, min_bucket_rows: int = 64):
        # the Eq-3 b_col is C's width for SpMM-SpMM (D1 = a1 @ c)
        self.b_col = c_col if b_is_sparse else b_col
        self.c_col = c_col
        self.b_is_sparse = b_is_sparse
        self.p = p
        self.cache_size = cache_size
        self.ct_size = ct_size
        self.width_cap = width_cap
        self.backend = backend
        self.max_dirty_frac = max_dirty_frac
        self.expected_reuse = expected_reuse
        self.min_bucket_rows = min_bucket_rows
        self._residents: dict = {}
        self.stats = {"requests": 0, "exact_hits": 0, "incremental": 0,
                      "rebuilds": 0}

    # -- bucket choice ----------------------------------------------------
    def _quantize(self, n: int) -> int:
        n = max(int(n), self.min_bucket_rows, 1)
        return 1 << (n - 1).bit_length()

    def bucket_for(self, a: CSR) -> tuple:
        """The ``(rows, cols, width_cap)`` bucket serving ``a`` — pow2
        shape quantization when ``serving_bucket_price`` says the padded
        traffic undercuts the amortized inspection, exact shape when it
        doesn't (an exact-shape bucket still shares its one cache slot)."""
        cap = api._resolve_width_cap(a, self.width_cap)
        cap_q = None if cap is None else 1 << (max(cap, 1) - 1).bit_length()
        r_pad, c_pad = self._quantize(a.n_rows), self._quantize(a.n_cols)
        price = cost_model.serving_bucket_price(
            n_rows=a.n_rows, n_pad=r_pad, nnz=a.nnz, b_col=self.b_col,
            c_col=self.c_col, expected_reuse=self.expected_reuse)
        if not price["bucketed"]:
            r_pad, c_pad = a.n_rows, a.n_cols
        return (r_pad, c_pad, cap_q)

    def _spec(self, *, width_cap, bucket: tuple | None = None):
        """The tier's ``FusionSpec`` — one construction point so the
        lookup, the bucket publish, and the hot-path dispatch can never
        cut different cache keys."""
        return api.FusionSpec(p=self.p, cache_size=self.cache_size,
                              ct_size=self.ct_size, uniform_split=True,
                              width_cap=width_cap, bucket=bucket)

    # -- schedule resolution ----------------------------------------------
    def schedule_for(self, a: CSR) -> tuple:
        """Resolve (entry, padded_csr, how) for a request; ``how`` is
        "hit" / "incremental" / "rebuild"."""
        bucket = self.bucket_for(a)
        ap = pad_csr(a, bucket[0], bucket[1])
        digest = csr_content_digest(ap)
        self.stats["requests"] += 1
        res = self._residents.get(bucket)
        if res is not None and res.digest == digest:
            self.stats["exact_hits"] += 1
            entry = api.get_schedule(
                ap, b_col=self.b_col, c_col=self.c_col,
                b_is_sparse=self.b_is_sparse,
                spec=self._spec(width_cap=bucket[2], bucket=bucket))
            return entry, ap, "hit"
        if res is not None:
            dirty = csr_dirty_rows(res.a, ap)
            limit = max(self.max_dirty_frac * ap.n_rows, 1.0)
            if dirty is not None and dirty.size <= limit:
                patched = incremental_update(res.a, res.entry, ap, dirty,
                                             cache_size=self.cache_size)
                if patched is not None:
                    api.store_bucket_schedule(
                        patched, bucket=bucket, patched=True,
                        spec=self._spec(width_cap=bucket[2]))
                    self._residents[bucket] = _Resident(ap, digest, patched)
                    self.stats["incremental"] += 1
                    return patched, ap, "incremental"
        entry = api.get_schedule(
            ap, b_col=self.b_col, c_col=self.c_col,
            b_is_sparse=self.b_is_sparse,
            spec=self._spec(width_cap=bucket[2], bucket=bucket))
        entry = self._with_headroom(ap, entry, bucket)
        self._residents[bucket] = _Resident(ap, digest, entry)
        self.stats["rebuilds"] += 1
        return entry, ap, "rebuild"

    def _with_headroom(self, ap: CSR, entry: api.ScheduleEntry,
                       bucket: tuple) -> api.ScheduleEntry:
        """Reserve wavefront-1 capacity for future patches (row slots for
        ``max_dirty_frac`` of the bucket plus spill lanes for their tails)
        and publish the padded entry under the bucket key."""
        slack = int(np.ceil(self.max_dirty_frac * ap.n_rows)) + 8
        counts = np.diff(ap.indptr)
        avg = float(counts.mean()) if counts.size else 1.0
        spill_slack = slack * int(max(2.0 * avg, 8.0))
        ds = pad_device_schedule(entry.dsched, j1_slots=slack,
                                 spill_slots=spill_slack)
        tm = ds.hbm_traffic_model(entry.b_col, entry.c_col)
        tm["packed_ell_bytes"] = api._packed_ell_bytes(ap, ds,
                                                       entry.b_is_sparse)
        padded = dataclasses.replace(entry, dsched=ds, traffic_model=tm,
                                     content_digest=csr_content_digest(ap))
        return api.store_bucket_schedule(
            padded, bucket=bucket, spec=self._spec(width_cap=bucket[2]))

    # -- the hot path -----------------------------------------------------
    def matmul(self, a: CSR, b_or_a1, c):
        """``D = a @ (b_or_a1 @ c)`` through the bucket's schedule; the
        operands are zero-padded to the bucket shape on the way in and the
        result sliced back to ``a.n_rows`` rows on the way out."""
        entry, ap, _ = self.schedule_for(a)
        bucket = entry.bucket
        c = jnp.asarray(c)
        if self.b_is_sparse:
            if not isinstance(b_or_a1, CSR):
                raise ValueError("tier built with b_is_sparse=True needs a "
                                 "CSR op-1")
            a1 = b_or_a1
            if (a1.n_rows, a1.n_cols) == (a.n_rows, a.n_cols):
                # self-multiply (D = A(AC)): pad both sides, and C's rows
                op1 = pad_csr(a1, bucket[1], bucket[1])
                cp = jnp.pad(c, ((0, bucket[1] - c.shape[0]), (0, 0)))
            else:
                op1 = pad_csr(a1, bucket[1], a1.n_cols)
                cp = c
        else:
            b = jnp.asarray(b_or_a1)
            if b.shape[1] != self.b_col:
                raise ValueError(f"b has {b.shape[1]} columns, tier serves "
                                 f"b_col={self.b_col}")
            op1 = jnp.pad(b, ((0, bucket[1] - b.shape[0]), (0, 0)))
            cp = c
        if cp.shape[1] != self.c_col:
            raise ValueError(f"c has {cp.shape[1]} columns, tier serves "
                             f"c_col={self.c_col}")
        d = api.tile_fused_matmul(
            ap, op1, cp, backend=self.backend,
            spec=self._spec(width_cap=bucket[2], bucket=bucket))
        return d[: a.n_rows]

    def hit_rate(self) -> float:
        """Fraction of requests served without a full Algorithm-1 run
        (exact digest hits + incremental patches)."""
        served = self.stats["exact_hits"] + self.stats["incremental"]
        return served / max(self.stats["requests"], 1)
