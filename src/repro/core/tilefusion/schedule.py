"""Device-side (static-shape) representation of a fused schedule.

XLA and Pallas need static shapes, so the host-side ragged ``Schedule`` is
padded once per sparsity pattern:

  wavefront 0: ``T0`` tiles, each with a contiguous first-op row range
    (padded to ``t_pad`` rows) and up to ``j0_max`` fused second-op rows whose
    A-rows are stored in *tile-local* ELL (column index relative to the tile's
    ``i_start`` — by the fusion criterion every dependency is in-tile).
  wavefront 1: ``T1`` tiles of second-op rows in *global* ELL over D1.

Padding conventions: padded fused-row slots use row index ``n_j`` (scatter
mode='drop'); padded ELL slots use col 0 / val 0.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.formats import CSR, csr_gather_rows, ell_slot_coords
from .scheduler import Schedule


@dataclasses.dataclass(frozen=True)
class DeviceSchedule:
    n_i: int
    n_j: int
    t_pad: int
    # wavefront 0
    i_starts: np.ndarray      # (T0,) int32
    i_lens: np.ndarray        # (T0,) int32
    j_rows0: np.ndarray       # (T0, j0_max) int32, pad = n_j
    ell_cols0: np.ndarray     # (T0, j0_max, w0) int32, tile-LOCAL, pad 0
    ell_vals0: np.ndarray     # (T0, j0_max, w0) f32, pad 0
    # wavefront 1
    j_rows1: np.ndarray       # (T1, j1_max) int32, pad = n_j
    ell_cols1: np.ndarray     # (T1, j1_max, w1) int32, GLOBAL, pad 0
    ell_vals1: np.ndarray     # (T1, j1_max, w1) f32, pad 0

    @property
    def n_tiles0(self) -> int:
        return int(self.i_starts.shape[0])

    @property
    def n_tiles1(self) -> int:
        return int(self.j_rows1.shape[0])

    def padded_flops_overhead(self, b_col: int, c_col: int) -> float:
        """Ratio of padded to useful FLOPs (perf accounting for §Roofline)."""
        useful = float(self.i_lens.sum()) * b_col * c_col
        padded = float(self.n_tiles0 * self.t_pad) * b_col * c_col
        return padded / max(useful, 1.0)

    def wf1_unique_deps(self) -> int:
        """Distinct D1 rows the post-barrier wavefront reads."""
        valid = self.j_rows1 < self.n_j
        if not valid.any():
            return 0
        cols = self.ell_cols1[valid]
        vals = self.ell_vals1[valid]
        return int(np.unique(cols[vals != 0]).shape[0])

    def hbm_traffic_model(self, b_col: int, c_col: int,
                          dtype_bytes: int = 4) -> dict:
        """Exact fast-memory traffic prediction for the kernel path.

        Unfused: D1 is written to and re-read from HBM in full.  Tile-fused:
        wavefront-0 consumers read D1 from VMEM; only the rows wavefront 1
        needs are spilled (beyond-paper optimization — the paper keeps D1
        resident in DRAM on CPU; on TPU we elide the unneeded writes).
        """
        n_i, n_j = self.n_i, self.n_j
        nnz0 = float((self.ell_vals0 != 0).sum())
        nnz1 = float((self.ell_vals1 != 0).sum())
        base = (n_i * b_col          # read B
                + n_j * c_col        # write D
                + (nnz0 + nnz1) * 2  # A vals + idx
                + b_col * c_col)     # C
        d1_rt = 2.0 * n_i * c_col    # unfused: D1 write + re-read
        spill = self.wf1_unique_deps()
        d1_fused = 2.0 * spill * c_col
        unfused = (base + d1_rt) * dtype_bytes
        fused = (base + d1_fused) * dtype_bytes
        return {"unfused_bytes": unfused, "fused_bytes": fused,
                "traffic_saving": 1.0 - fused / unfused,
                "d1_spill_rows": spill}


def _ell_arrays(a: CSR, j_rows_list, j_max, pad_row, local_start=None):
    """Pack ragged per-tile row lists into (T, j_max, w) ELL in one shot.

    Flat index arithmetic instead of nested Python loops: every nonzero's
    (tile, slot, width) scatter coordinate is derived from ``indptr`` diffs
    (``csr_gather_rows`` + ``ell_slot_coords``), so packing is O(nnz)
    regardless of tile count."""
    n_tiles = len(j_rows_list)
    sizes = np.asarray([jr.size for jr in j_rows_list], dtype=np.int64)
    all_j = np.concatenate(j_rows_list).astype(np.int64) if n_tiles \
        else np.zeros(0, np.int64)
    row_nnz = (a.indptr[all_j + 1] - a.indptr[all_j]).astype(np.int64) \
        if all_j.size else np.zeros(0, np.int64)
    w = max(int(row_nnz.max()) if row_nnz.size else 0, 1)
    j_rows = np.full((n_tiles, j_max), pad_row, dtype=np.int32)
    cols = np.zeros((n_tiles, j_max, w), dtype=np.int32)
    vals = np.zeros((n_tiles, j_max, w), dtype=np.float32)
    if all_j.size:
        # (tile, slot) of every packed row, then (row, width-slot) per nnz
        tile_of, slot_of = ell_slot_coords(sizes)
        j_rows[tile_of, slot_of] = all_j
        flat, lens = csr_gather_rows(a, all_j)
        if flat.size:
            row_rep, w_idx = ell_slot_coords(lens)
            tv, sv = tile_of[row_rep], slot_of[row_rep]
            c = a.indices[flat].astype(np.int64)
            if local_start is not None:
                c = c - np.asarray(local_start, np.int64)[tv]
            cols[tv, sv, w_idx] = c.astype(np.int32)
            vals[tv, sv, w_idx] = a.data[flat].astype(np.float32)
    return j_rows, cols, vals


def to_device_schedule(a: CSR, sched: Schedule) -> DeviceSchedule:
    wf0, wf1 = sched.wavefronts
    n_i, n_j = sched.n_i, sched.n_j

    t_pad = max([tl.n_i for tl in wf0] + [1])
    j0_max = max([tl.n_j for tl in wf0] + [1])
    i_starts = np.asarray([tl.i_start for tl in wf0], dtype=np.int32)
    i_lens = np.asarray([tl.n_i for tl in wf0], dtype=np.int32)
    starts = np.asarray([tl.i_start for tl in wf0], dtype=np.int32)
    j_rows0, cols0, vals0 = _ell_arrays(
        a, [tl.j_rows for tl in wf0], j0_max, pad_row=n_j, local_start=starts)

    if wf1:
        j1_max = max(tl.n_j for tl in wf1)
        j_rows1, cols1, vals1 = _ell_arrays(
            a, [tl.j_rows for tl in wf1], max(j1_max, 1), pad_row=n_j)
    else:
        j_rows1 = np.full((0, 1), n_j, dtype=np.int32)
        cols1 = np.zeros((0, 1, 1), dtype=np.int32)
        vals1 = np.zeros((0, 1, 1), dtype=np.float32)

    return DeviceSchedule(
        n_i=n_i, n_j=n_j, t_pad=int(t_pad),
        i_starts=i_starts, i_lens=i_lens,
        j_rows0=j_rows0, ell_cols0=cols0, ell_vals0=vals0,
        j_rows1=j_rows1, ell_cols1=cols1, ell_vals1=vals1,
    )
