"""Device-side (static-shape) representation of a fused schedule.

XLA and Pallas need static shapes, so the host-side ragged ``Schedule`` is
padded once per sparsity pattern:

  wavefront 0: ``T0`` tiles, each with a contiguous first-op row range
    (padded to ``t_pad`` rows) and up to ``j0_max`` fused second-op rows whose
    A-rows are stored in *tile-local* ELL (column index relative to the tile's
    ``i_start`` — by the fusion criterion every dependency is in-tile).
  wavefront 1: ``T1`` tiles of second-op rows in *global* ELL over D1.

Padding conventions: padded fused-row slots use row index ``n_j`` (scatter
mode='drop'); padded ELL slots use col 0 / val 0.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..sparse.formats import CSR, csr_gather_rows, ell_slot_coords
from .scheduler import Schedule


@dataclasses.dataclass(frozen=True)
class DeviceSchedule:
    n_i: int
    n_j: int
    t_pad: int
    # wavefront 0
    i_starts: np.ndarray      # (T0,) int32
    i_lens: np.ndarray        # (T0,) int32
    j_rows0: np.ndarray       # (T0, j0_max) int32, pad = n_j
    ell_cols0: np.ndarray     # (T0, j0_max, w0) int32, tile-LOCAL, pad 0
    ell_vals0: np.ndarray     # (T0, j0_max, w0) f32, pad 0
    # wavefront 1 (hybrid: body ELL capped at width_cap + COO spill lanes)
    j_rows1: np.ndarray       # (T1, j1_max) int32, pad = n_j
    ell_cols1: np.ndarray     # (T1, j1_max, w1) int32, GLOBAL, pad 0
    ell_vals1: np.ndarray     # (T1, j1_max, w1) f32, pad 0
    #: Hub-row tails past ``width_cap``, as flat COO over (D row, D1 row):
    #: executors apply them with one scatter-add after the wf1 body pass.
    #: Empty when ``width_cap`` is None (pad-to-max packing, pre-cap layout).
    spill_rows1: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))   # global D row
    spill_cols1: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))   # global D1 row
    spill_vals1: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.float32))
    width_cap: int | None = None

    @property
    def n_tiles0(self) -> int:
        return int(self.i_starts.shape[0])

    @property
    def n_tiles1(self) -> int:
        return int(self.j_rows1.shape[0])

    def padded_flops_overhead(self, b_col: int, c_col: int) -> float:
        """Ratio of padded to useful FLOPs (perf accounting for §Roofline)."""
        useful = float(self.i_lens.sum()) * b_col * c_col
        padded = float(self.n_tiles0 * self.t_pad) * b_col * c_col
        return padded / max(useful, 1.0)

    def wf1_dep_rows(self) -> np.ndarray:
        """Sorted distinct D1 rows the post-barrier wavefront reads (body +
        spill).  This is the *halo* of the schedule: under a sharded
        partition these are the only rows that must cross device
        boundaries, so the sharded executors all-gather exactly this set.

        Memoized on the (immutable) instance — the sharded dispatch reads
        it twice per build (layout choice, then halo tables), and the
        O(nnz) unique scan should run once per schedule, not per read."""
        memo = getattr(self, "_wf1_dep_rows_memo", None)
        if memo is not None:
            return memo
        memo = self._wf1_dep_rows_build()
        object.__setattr__(self, "_wf1_dep_rows_memo", memo)
        return memo

    def _wf1_dep_rows_build(self) -> np.ndarray:
        valid = self.j_rows1 < self.n_j
        parts = []
        if valid.any():
            cols = self.ell_cols1[valid]
            vals = self.ell_vals1[valid]
            parts.append(cols[vals != 0])
        if self.spill_cols1.size:
            # same explicit-zero filter as the body pass, so the count (and
            # with it the traffic model) stays invariant to the width cap
            parts.append(self.spill_cols1[self.spill_vals1 != 0])
        if not parts:
            return np.zeros(0, np.int64)
        return np.unique(np.concatenate(parts)).astype(np.int64)

    def wf1_unique_deps(self) -> int:
        """Distinct D1 rows the post-barrier wavefront reads (body + spill,
        so the count is invariant to the width cap)."""
        return int(self.wf1_dep_rows().shape[0])

    def hbm_traffic_model(self, b_col: int, c_col: int,
                          dtype_bytes: int = 4) -> dict:
        """Exact fast-memory traffic prediction for the kernel path.

        Unfused: D1 is written to and re-read from HBM in full.  Tile-fused:
        wavefront-0 consumers read D1 from VMEM; only the rows wavefront 1
        needs are spilled (beyond-paper optimization — the paper keeps D1
        resident in DRAM on CPU; on TPU we elide the unneeded writes).

        ``dtype_bytes`` is the *value* itemsize of the dense operands
        (bf16 = 2, f32 = 4, f64 = 8); index traffic is always int32, so the
        sparse operand's column indices are priced at 4 bytes regardless.
        """
        n_i, n_j = self.n_i, self.n_j
        nnz0 = float((self.ell_vals0 != 0).sum())
        nnz1 = float((self.ell_vals1 != 0).sum()) \
            + float((self.spill_vals1 != 0).sum())
        vals = (n_i * b_col          # read B
                + n_j * c_col        # write D
                + (nnz0 + nnz1)      # A vals
                + b_col * c_col)     # C
        idx_bytes = (nnz0 + nnz1) * 4.0   # A idx, int32 at any value dtype
        d1_rt = 2.0 * n_i * c_col    # unfused: D1 write + re-read
        spill = self.wf1_unique_deps()
        d1_fused = 2.0 * spill * c_col
        unfused = (vals + d1_rt) * dtype_bytes + idx_bytes
        fused = (vals + d1_fused) * dtype_bytes + idx_bytes
        return {"unfused_bytes": unfused, "fused_bytes": fused,
                "traffic_saving": 1.0 - fused / unfused,
                "d1_spill_rows": spill, "dtype_bytes": int(dtype_bytes)}


def _ell_arrays(a: CSR, j_rows_list, j_max, pad_row, local_start=None,
                width_cap=None):
    """Pack ragged per-tile row lists into (T, j_max, w) ELL in one shot.

    Flat index arithmetic instead of nested Python loops: every nonzero's
    (tile, slot, width) scatter coordinate is derived from ``indptr`` diffs
    (``csr_gather_rows`` + ``ell_slot_coords``), so packing is O(nnz)
    regardless of tile count.

    ``width_cap`` bounds the body width (hybrid layout): entries past slot
    ``width_cap`` of a row come back as flat COO spill lanes
    ``(spill_rows, spill_cols, spill_vals)`` — global row ids, *global*
    columns (spill is only used for wavefront 1, after the barrier, where
    tile-locality no longer applies; ``local_start`` must be None with a
    cap).  With ``width_cap=None`` the spill arrays are empty and the body
    is the exact pre-cap pad-to-max layout."""
    assert width_cap is None or local_start is None, \
        "capped packing is global-column (wavefront 1) only"
    n_tiles = len(j_rows_list)
    sizes = np.asarray([jr.size for jr in j_rows_list], dtype=np.int64)
    all_j = np.concatenate(j_rows_list).astype(np.int64) if n_tiles \
        else np.zeros(0, np.int64)
    row_nnz = (a.indptr[all_j + 1] - a.indptr[all_j]).astype(np.int64) \
        if all_j.size else np.zeros(0, np.int64)
    w = max(int(row_nnz.max()) if row_nnz.size else 0, 1)
    if width_cap is not None:
        w = max(min(int(width_cap), w), 1)
    j_rows = np.full((n_tiles, j_max), pad_row, dtype=np.int32)
    cols = np.zeros((n_tiles, j_max, w), dtype=np.int32)
    vals = np.zeros((n_tiles, j_max, w), dtype=np.float32)
    spill_rows = np.zeros(0, np.int32)
    spill_cols = np.zeros(0, np.int32)
    spill_vals = np.zeros(0, np.float32)
    if all_j.size:
        # (tile, slot) of every packed row, then (row, width-slot) per nnz
        tile_of, slot_of = ell_slot_coords(sizes)
        j_rows[tile_of, slot_of] = all_j
        flat, lens = csr_gather_rows(a, all_j)
        if flat.size:
            row_rep, w_idx = ell_slot_coords(lens)
            body = w_idx < w
            if not body.all():
                sp = ~body
                spill_rows = all_j[row_rep[sp]].astype(np.int32)
                spill_cols = a.indices[flat[sp]].astype(np.int32)
                spill_vals = a.data[flat[sp]].astype(np.float32)
                row_rep, w_idx, flat = row_rep[body], w_idx[body], flat[body]
            tv, sv = tile_of[row_rep], slot_of[row_rep]
            c = a.indices[flat].astype(np.int64)
            if local_start is not None:
                c = c - np.asarray(local_start, np.int64)[tv]
            cols[tv, sv, w_idx] = c.astype(np.int32)
            vals[tv, sv, w_idx] = a.data[flat].astype(np.float32)
    return j_rows, cols, vals, (spill_rows, spill_cols, spill_vals)


def pad_device_schedule(ds: DeviceSchedule, *, j1_slots: int = 0,
                        spill_slots: int = 0) -> DeviceSchedule:
    """Append no-op wavefront-1 capacity to a device schedule.

    Headroom for the incremental inspector: extra row slots (row index
    ``n_j`` → scatter mode='drop', zero ELL entries) and extra spill lanes
    (val 0 → scatter-add no-op) let later patches move rows into
    wavefront 1 without changing any array shape — a shape change would
    recompile the jitted executors a serving bucket exists to share.
    Called once per bucket build, never on the hot path."""
    if j1_slots <= 0 and spill_slots <= 0:
        return ds
    j_rows1, cols1, vals1 = ds.j_rows1, ds.ell_cols1, ds.ell_vals1
    if j1_slots > 0:
        t1, j1 = j_rows1.shape
        if t1 == 0:
            # fully-fused schedule: stand up one wavefront-1 tile of pure
            # pad slots (body width from the cap so entering rows mostly
            # land in the body, not the spill lanes)
            w = max(ds.width_cap if ds.width_cap is not None else 1, 1)
            j_rows1 = np.full((1, j1_slots), ds.n_j, np.int32)
            cols1 = np.zeros((1, j1_slots, w), np.int32)
            vals1 = np.zeros((1, j1_slots, w), np.float32)
        else:
            w = cols1.shape[2]
            extra = -(-j1_slots // max(j1, 1))
            j_rows1 = np.concatenate(
                [j_rows1, np.full((extra, j1), ds.n_j, np.int32)])
            cols1 = np.concatenate(
                [cols1, np.zeros((extra, j1, w), np.int32)])
            vals1 = np.concatenate(
                [vals1, np.zeros((extra, j1, w), np.float32)])
    sr, sc, sv = ds.spill_rows1, ds.spill_cols1, ds.spill_vals1
    if spill_slots > 0:
        sr = np.concatenate([sr, np.zeros(spill_slots, np.int32)])
        sc = np.concatenate([sc, np.zeros(spill_slots, np.int32)])
        sv = np.concatenate([sv, np.zeros(spill_slots, np.float32)])
    return dataclasses.replace(ds, j_rows1=j_rows1, ell_cols1=cols1,
                               ell_vals1=vals1, spill_rows1=sr,
                               spill_cols1=sc, spill_vals1=sv)


def to_device_schedule(a: CSR, sched: Schedule,
                       width_cap: int | None = None) -> DeviceSchedule:
    """Pad the host schedule to static shapes.

    ``width_cap`` bounds the wavefront-1 ELL body width (hub rows land in
    wavefront 1 — their dependencies span tiles — so this is where one
    max-degree row otherwise inflates the whole (T1, j1_max, w1) block);
    the capped tails come out as the schedule's COO spill lanes.  Wavefront
    0's tile-local ELL is never capped: a fused row's width is already
    bounded by the tile size, and the Pallas kernels consume it as-is."""
    wf0, wf1 = sched.wavefronts
    n_i, n_j = sched.n_i, sched.n_j

    t_pad = max([tl.n_i for tl in wf0] + [1])
    j0_max = max([tl.n_j for tl in wf0] + [1])
    i_starts = np.asarray([tl.i_start for tl in wf0], dtype=np.int32)
    i_lens = np.asarray([tl.n_i for tl in wf0], dtype=np.int32)
    starts = np.asarray([tl.i_start for tl in wf0], dtype=np.int32)
    j_rows0, cols0, vals0, _ = _ell_arrays(
        a, [tl.j_rows for tl in wf0], j0_max, pad_row=n_j, local_start=starts)

    spill1 = (np.zeros(0, np.int32), np.zeros(0, np.int32),
              np.zeros(0, np.float32))
    if wf1:
        j1_max = max(tl.n_j for tl in wf1)
        j_rows1, cols1, vals1, spill1 = _ell_arrays(
            a, [tl.j_rows for tl in wf1], max(j1_max, 1), pad_row=n_j,
            width_cap=width_cap)
    else:
        j_rows1 = np.full((0, 1), n_j, dtype=np.int32)
        cols1 = np.zeros((0, 1, 1), dtype=np.int32)
        vals1 = np.zeros((0, 1, 1), dtype=np.float32)

    return DeviceSchedule(
        n_i=n_i, n_j=n_j, t_pad=int(t_pad),
        i_starts=i_starts, i_lens=i_lens,
        j_rows0=j_rows0, ell_cols0=cols0, ell_vals0=vals0,
        j_rows1=j_rows1, ell_cols1=cols1, ell_vals1=vals1,
        spill_rows1=spill1[0], spill_cols1=spill1[1], spill_vals1=spill1[2],
        width_cap=width_cap,
    )
