"""Unified tile-fusion dispatch — the single fused-matmul entrypoint.

``tile_fused_matmul(a, b_or_a1, c)`` computes ``D = a @ (b_or_a1 @ c)``
(GeMM-SpMM when ``b_or_a1`` is dense, SpMM-SpMM when it is a ``CSR``) and
owns the two decisions every call site used to repeat by hand:

  1. **Inspector amortization (paper §4.2.3).**  The Algorithm-1 scheduler
     runs once per (matrix content, tile size, cache budget) and the
     resulting ``DeviceSchedule`` is memoized in a content-keyed cache; a
     second call with the same sparsity pattern skips inspection entirely.
     This is the inspector/executor separation of sparse tiling
     (Cheshmi et al.) realized as a process-wide cache.

  2. **Executor selection (Eq. 3 + capability).**  ``backend="auto"`` picks
     between the Pallas wavefront-0 kernels (uniform schedules on capable
     hardware — TPU, or interpret mode forced via ``PALLAS_INTERPRET=1``;
     both GeMM-SpMM and SpMM-SpMM lower), the XLA vmapped executor, and the
     unfused two-call baseline using the schedule's Eq-3 traffic model:
     patterns that fuse nothing (or would move more bytes fused than
     unfused) fall back to the unfused code.  Benchmarks pass an explicit
     ``backend=`` override.

**Hybrid-ELL width cap (``width_cap``).**  Every ELL the executors stream
(wavefront-1 body, SpMM-SpMM op-1, the unfused full-matrix format) is
packed by the shared ``formats.HybridELL`` packer with a width cap —
"auto" picks the traffic-optimal cap from the degree distribution, so one
max-degree hub row of a power-law graph no longer inflates the padded
allocation; the capped tails travel as COO spill lanes applied with one
scatter-add.  The resolved cap is part of the schedule and ELL cache keys,
and the autotune sweep tries candidate caps alongside tile sizes.

**Tile-size autotuning (``autotune=True``).**  ``get_schedule`` /
``tile_fused_matmul`` accept ``autotune=True`` to sweep a small
``ct_size`` × ``cache_size`` grid (``AUTOTUNE_CT_GRID`` ×
``AUTOTUNE_CACHE_SCALES``, plus the caller's own knobs) and keep the
candidate whose Eq-3 predicted fast-memory traffic, scaled by the
schedule's padded-FLOPs overhead, scores best.  The winner is pinned so it
never predicts more traffic than the default ``ct_size=2048`` schedule, and
the sweep result is
memoized in the same content-keyed cache: one sweep per pattern, every
later call is a hit.  The vectorized O(nnz) inspector is what makes the
sweep affordable (candidate count × inspection cost).

**Cache budget.**  Both the schedule cache and the full-matrix ELL cache
are LRU-bounded at ``REPRO_SCHEDULE_CACHE_ENTRIES`` entries each (env var,
default 128); streaming workloads that touch unbounded pattern sets evict
oldest-first instead of growing without bound.
``schedule_cache_stats()`` reports hits/misses/evictions plus live entry
counts of both caches.

**One knob object (``spec=``).**  Every dispatch knob below lives on a
frozen ``FusionSpec`` (``spec.py``) and callers pass ``spec=``; the spec's
resolved form (width cap concretized, mesh reduced to ``mesh_key``, inert
shard knobs collapsed on trivial meshes) is the schedule-cache key tail —
shared verbatim by the content key, the autotune key, the bucket publish,
and the custom_vjp backward, so a knob cannot steer dispatch without
keying the cache.  The historical keyword surface (``p=``, ``ct_size=``,
``mesh=``, ...) still works as a deprecation shim that builds the spec and
warns once per process.

**Sharded dispatch (``spec.mesh``).**  A non-trivial ``jax.sharding.Mesh``
partitions the wavefront-0 fused-tile grid row-block over the mesh's row
shards, contiguous tile groups balanced by their Eq-3 cost; the per-shard
executor runs under ``shard_map`` (wavefront 0 is communication-free by
the fusion criterion) and the wavefront-1 halo rows are all-gathered over
the row axis.  The output combine is chosen by priced bytes
(``shard_combine="auto"``): the row-remapped reduce-scatter emits
per-shard owner blocks (zero combine collectives — partials are
owner-disjoint by construction) with psum retained as the simple
fallback.  Multi-axis meshes can split the dense operand's columns over
the trailing axis (``shard_layout="1.5d"``) or additionally peel a depth
axis that replicates wavefront-0 compute and splits the wavefront-1 halo
per depth layer (``"2.5d"``, staged per-layer halo gathers + one depth
psum); ``cost_model.choose_mesh_layout`` weighs all rungs — and the
single-device fallback — by per-device critical-path bytes.
``spec.overlap`` ("auto" | bool) issues the wavefront-1 halo all-gather
*before* the wavefront-0 body so the collective hides under
communication-free compute (double-buffered halo tables;
``shard_comm_model`` prices the hidden bytes as free only up to the
modeled wf0 window).  ``spec.n_repl`` pins the total operand-replication
factor the layout must provide.  The mesh's (axis names, shape) plus the
shard knobs join the schedule-cache key; ``schedule_cache_stats()``
reports mesh-keyed entries as ``mesh_entries`` with per-layout counters
(``layout_1d`` / ``layout_15d`` / ``layout_25d`` / ``layout_fallback``)
plus ``spec_entries`` (distinct resolved specs among live keys), and a
trivial mesh falls back to single-device dispatch.  CPU CI exercises the
real multi-device path via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  See ``sharded.py``.

Everything outside ``core/tilefusion`` (models, examples, benchmarks) routes
through this module; later PRs extend the seam (GPU backend, new layout
rungs) by adding ``FusionSpec`` fields without touching call sites.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sparse.formats import (CSR, DEFAULT_WIDTH_QUANTILE,
                              csr_content_digest, hybrid_width_cap)
from . import cost_model, fused_ops, reorder, sharded
from .schedule import DeviceSchedule, to_device_schedule
from .scheduler import Schedule, build_schedule
from .spec import (FusionSpec, reset_legacy_warning,  # noqa: F401 (re-export)
                   spec_from_legacy_kwargs)


def _shard_for_mesh(a: CSR, sched, dsched, mk: tuple, *, b_col: int,
                    c_col: int, b_is_sparse: bool, width_cap,
                    shard_combine: str, shard_layout: str,
                    dtype_bytes: int = 4, overlap="auto",
                    n_repl: int | None = None, serial_bytes: float = 0.0):
    """Mesh-shape-aware shard build: resolve how the mesh's axes are used
    (pure-1D row shards, 1.5D row × column-replica, 2.5D row × replica ×
    depth) and which output combine runs, then build the per-shard
    schedule.

    ``shard_layout="auto"`` consults ``cost_model.choose_mesh_layout``,
    which weighs every layout's per-device critical-path bytes (halo
    discounted by the ``overlap`` window, combine, depth psum) plus the
    serial compute split across the row shards against the operand bytes
    replication copies; when the chooser's winner is the single-device
    fallback, the entry carries ``shard=None`` and dispatch stays
    Eq-3-consistent with ``select_backend``.  ``n_repl`` restricts the
    candidates to layouts whose total replication factor (column replicas
    × depth) matches, or — with an explicit layout — validates it.
    ``shard_combine="auto"`` defers to ``shard_comm_model``'s
    psum-vs-reduce-scatter pricing inside the builder."""
    from .scheduler import resolve_mesh_layout
    shape = mk[1]
    layout = shard_layout
    # wf0's Eq-3 share bounds the overlap window the chooser prices; the
    # builder re-resolves "auto" overlap with its exact per-tile costs
    wf0_bytes = float(serial_bytes) * float(getattr(sched, "fused_ratio",
                                                    0.0))
    if layout == "auto":
        operand_bytes = (
            float(a.nnz) * (dtype_bytes + cost_model.INDEX_BYTES)
            + float(dsched.n_i * b_col) * dtype_bytes)
        choice = cost_model.choose_mesh_layout(
            shape, halo_rows=int(dsched.wf1_dep_rows().shape[0]),
            n_i=dsched.n_i, n_j=dsched.n_j, c_col=c_col,
            operand_bytes=operand_bytes, dtype_bytes=dtype_bytes,
            serial_bytes=float(serial_bytes), overlap=overlap,
            wf0_bytes=wf0_bytes)
        if n_repl is not None:
            cands = {k: v for k, v in choice["candidates"].items()
                     if k != "fallback"
                     and v["n_repl"] * v["n_depth"] == int(n_repl)}
            if not cands:
                raise ValueError(
                    f"n_repl={n_repl} is unsatisfiable on mesh shape "
                    f"{shape}: no layout replicates the operands "
                    f"{n_repl}x")
            rank = ("total_per_device" if serial_bytes > 0.0
                    else "total_bytes")
            layout = min(cands, key=lambda k: cands[k][rank])
        else:
            layout = choice["layout"]
        if layout == "fallback":
            return None
    else:
        _, nr, nd = resolve_mesh_layout(shape, layout)
        if n_repl is not None and nr * nd != int(n_repl):
            raise ValueError(
                f"n_repl={n_repl} does not match layout {layout!r} on "
                f"mesh shape {shape} (resolves to {nr}x{nd} replicas)")
    return sharded.build_sharded_schedule(
        a, sched, dsched, shape, b_col=b_col, c_col=c_col,
        b_is_sparse=b_is_sparse, width_cap=width_cap, layout=layout,
        combine=shard_combine, dtype_bytes=dtype_bytes, overlap=overlap)


def _shard_knobs_key(mk: tuple | None, shard_combine: str,
                     shard_layout: str) -> tuple:
    """Validated cache-key component for the sharding knobs: a typo'd knob
    must fail loudly (never silently fall back to another layout), and on
    a trivial mesh the pair collapses to (None, None) so ``mesh=None`` and
    a 1-device mesh keep sharing entries regardless of the (then inert)
    knob values."""
    from .scheduler import MESH_LAYOUTS
    if shard_combine not in sharded.COMBINE_MODES + ("auto",):
        raise ValueError(
            f"shard_combine={shard_combine!r}; expected one of "
            f"{sharded.COMBINE_MODES + ('auto',)}")
    if shard_layout not in MESH_LAYOUTS + ("auto",):
        raise ValueError(f"shard_layout={shard_layout!r}; expected one of "
                         f"{MESH_LAYOUTS + ('auto',)}")
    if mk is None:
        return (None, None)
    return (str(shard_combine), str(shard_layout))


def _coerce_spec(spec, legacy: dict, caller: str) -> FusionSpec:
    """Resolve the ``spec= | **legacy-kwargs`` surface to one FusionSpec.

    Mixing both raises (two sources of truth for one knob is exactly the
    bug class the spec removes); bare calls get the default spec."""
    if legacy:
        if spec is not None:
            raise TypeError(
                f"{caller}() got both spec= and legacy keyword(s) "
                f"{sorted(legacy)}; put every knob on the FusionSpec")
        return spec_from_legacy_kwargs(legacy, caller=caller)
    if spec is None:
        return FusionSpec()
    if not isinstance(spec, FusionSpec):
        raise TypeError(f"{caller}() spec= expects a FusionSpec, got "
                        f"{type(spec).__name__}")
    return spec


def _spec_key(spec: FusionSpec, *, cap, mk, sk) -> tuple:
    """THE resolved-spec cache-key tail, shared by every key site (content
    key, autotune key, bucket publish).  ``cap``/``mk``/``sk`` are the
    already-resolved width cap, mesh key, and shard-knob pair; on a
    trivial mesh the overlap/n_repl knobs are inert and collapse to None
    so ``mesh=None`` entries share regardless of their values.
    ``spec.dtype_bytes`` must be resolved (int) by the time a key is cut."""
    if mk is None:
        ov, nr = None, None
    else:
        ov = spec.overlap
        nr = None if spec.n_repl is None else int(spec.n_repl)
    return (int(spec.p), float(spec.cache_size), int(spec.ct_size),
            bool(spec.uniform_split), cap, mk, sk, ov, nr,
            bool(spec.transpose), int(spec.dtype_bytes), spec.reorder)


#: Valid ``backend=`` values for tile_fused_matmul.
BACKENDS = ("auto", "pallas", "xla", "unfused", "sharded")

#: Below this Eq-2 fused ratio the schedule fuses so little that the fused
#: executor's padding/scatter overhead cannot pay for itself — dispatch to
#: the unfused baseline instead.
MIN_FUSED_RATIO = 0.02

#: Minimum modeled Eq-3 traffic saving the tiled executors must clear.  The
#: byte model prices data movement only; the tile loop's fixed costs (per-
#: tile gathers, wavefront barrier, D1 scatter) are off-model, so a saving
#: in the low single digits reliably loses to the plain hybrid SpMM in wall
#: clock (measured on hub-heavy power-law graphs, where ~5% modeled saving
#: ran ~30% slower fused).  Friendly patterns (banded, block-diagonal)
#: model 25%+ and clear this floor easily.
MIN_TRAFFIC_SAVING = 0.10

#: The paper's ct_size heuristic (§4: ratio gains saturate past 2048); the
#: autotune sweep is anchored on it — the winner never predicts more Eq-3
#: traffic than this default.
DEFAULT_CT_SIZE = 2048

#: Coarse tile sizes the autotune sweep tries (the caller's ct_size and the
#: 2048 anchor are always added).
AUTOTUNE_CT_GRID = (512, 1024, 2048, 4096)

#: Cache-budget scales the sweep tries per tile size: the full budget and a
#: half budget (step 2 splits earlier, trading padding for locality).
AUTOTUNE_CACHE_SCALES = (1.0, 0.5)

#: Env var capping both the schedule cache and the ELL cache (entries).
CACHE_ENTRIES_ENV = "REPRO_SCHEDULE_CACHE_ENTRIES"
DEFAULT_CACHE_ENTRIES = 128


# --------------------------------------------------------------------------
# Inspector cache
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ScheduleEntry:
    """One memoized inspection: host schedule + device schedule + metadata.

    Entries live until evicted LRU (``REPRO_SCHEDULE_CACHE_ENTRIES``; the
    amortization contract: one pattern, many runs).  Workloads that stream
    *new* patterns either rely on the LRU bound or call
    ``clear_schedule_cache()`` between phases.
    """

    sched: Schedule
    dsched: DeviceSchedule
    b_col: int
    c_col: int
    b_is_sparse: bool
    inspector_s: float          # wall time of the one build (not per call)
    #: Eq-3-derived fast-memory traffic prediction, computed once at build
    #: (select_backend reads it on every "auto" call)
    traffic_model: dict = dataclasses.field(default_factory=dict)
    hits: int = 0               # cache hits since the build
    #: set on autotune winners: the (ct_size, cache_size, width_cap) the
    #: sweep picked
    autotuned: tuple | None = None
    #: resolved hybrid-ELL width cap the schedule was packed with (None =
    #: pad-to-max); part of the cache key, consumed by the executors
    width_cap: int | None = None
    #: ``sharded.mesh_key`` of the mesh this entry was inspected for (None
    #: for single-device entries); part of the cache key — the same matrix
    #: on a different mesh shape is a different schedule
    mesh_key: tuple | None = None
    #: per-shard restructuring (``sharded.ShardedSchedule``) when the entry
    #: was built for a non-trivial mesh and the grid is uniform; None means
    #: dispatch falls back to single-device execution
    shard: object = None
    #: content digest of the matrix this entry was inspected (or patched)
    #: for.  Bucket-keyed entries are looked up by *shape bucket*, not
    #: content, so the dispatch verifies this against the request before
    #: trusting a hit; None on autotune sweep entries
    content_digest: bytes | None = None
    #: the ``(rows, cols, width_cap)`` shape bucket this entry serves
    #: (``serving.ServingTier``), None for plain content-keyed entries
    bucket: tuple | None = None
    #: True when this entry was inspected on ``a.transpose()`` — the
    #: backward-pass schedule of the custom_vjp, keyed by the *forward*
    #: digest plus this bit so fwd and bwd entries live side by side
    transpose: bool = False
    #: itemsize of the dense operand the entry prices traffic for; part of
    #: the cache key (bf16 and f32 move different bytes through Eq 3)
    dtype_bytes: int = 4
    #: reorder transform baked into the schedule ("rcm" | "similarity";
    #: None = identity ordering — including ``reorder="auto"`` builds
    #: where no candidate cleared the Eq-3 floor)
    reorder: str | None = None
    #: the symmetric row/col permutation the schedule was inspected under
    #: (``perm[new] = old``) and its inverse; dispatch permutes the dense
    #: operands in and the output back out — callers never apply/undo it
    reorder_perm: np.ndarray | None = None
    reorder_inv: np.ndarray | None = None


_schedule_cache: "collections.OrderedDict" = collections.OrderedDict()
_ell_cache: "collections.OrderedDict" = collections.OrderedDict()
_stats = {"hits": 0, "misses": 0, "evictions": 0, "ell_evictions": 0,
          "autotune_sweeps": 0, "incremental_patches": 0}
_lock = threading.Lock()
#: The ELL cache has its own lock so its atomic check-and-build (which can
#: allocate a full-matrix padded ELL) never stalls schedule-cache hits.
#: Lock order where both are held: _lock, then _ell_lock.
_ell_lock = threading.Lock()


def _cache_budget() -> int:
    """Per-cache entry cap from ``REPRO_SCHEDULE_CACHE_ENTRIES`` (>= 1)."""
    raw = os.environ.get(CACHE_ENTRIES_ENV, "")
    try:
        return max(int(raw), 1)
    except ValueError:
        return DEFAULT_CACHE_ENTRIES


def _cache_get(cache, key):
    """LRU lookup; caller holds ``_lock``."""
    value = cache.get(key)
    if value is not None:
        cache.move_to_end(key)
    return value


def _cache_put(cache, key, value, evict_key: str = "evictions") -> None:
    """LRU insert with oldest-first eviction; caller holds the cache's lock.

    Each cache bumps its own eviction counter (``evict_key``) so the two
    locks never contend on one non-atomic ``+=``."""
    cache[key] = value
    cache.move_to_end(key)
    budget = _cache_budget()
    while len(cache) > budget:
        cache.popitem(last=False)
        _stats[evict_key] += 1


def _content_key(a: CSR) -> bytes:
    """Content hash of a CSR matrix (``formats.csr_content_digest``).  The
    schedule *structure* depends only on the pattern, but the DeviceSchedule
    bakes in the values (ELL), so the key covers both — same pattern with
    new values rebuilds, same matrix content always hits."""
    return csr_content_digest(a)


def _resolve_width_cap(a: CSR, width_cap) -> int | None:
    """Resolve the ``width_cap`` knob to a concrete cap (the cache key).

    ``"auto"`` derives the traffic-optimal cap from the matrix's own degree
    distribution (``formats.hybrid_width_cap``); ``None`` disables capping
    (pad-to-max, the pre-hybrid layout); an int is clamped to >= 1."""
    if width_cap is None:
        return None
    if width_cap == "auto":
        # memoized per CSR instance (treated as immutable, like the content
        # digest): the cap search sorts the degree distribution once, not
        # once per hot-path call
        cap = getattr(a, "_auto_width_cap", None)
        if cap is None:
            cap = hybrid_width_cap(np.diff(a.indptr))
            object.__setattr__(a, "_auto_width_cap", cap)
        return cap
    return max(int(width_cap), 1)


def _candidate_width_caps(a: CSR, caller_cap: int | None) -> list:
    """Caps the autotune sweep tries: the caller's, the traffic-optimal,
    the high-quantile, and pad-to-max (as an explicit max-degree cap)."""
    counts = np.diff(a.indptr)
    w_max = max(int(counts.max()), 1) if counts.size else 1
    caps = {w_max if caller_cap is None else caller_cap,
            hybrid_width_cap(counts),
            hybrid_width_cap(counts, DEFAULT_WIDTH_QUANTILE),
            w_max}
    return sorted(caps)


def _packed_ell_bytes(a: CSR, dsched: DeviceSchedule, b_is_sparse: bool,
                      dtype_bytes: int = 4) -> float:
    """Bytes the executors stream for the *packed* sparse operands: the
    wavefront-1 hybrid body (col+val per slot, padding included) plus 3
    elements per spill lane, and — for SpMM-SpMM — the op-1 hybrid at the
    schedule's cap (op-1 ≈ A, the cost model's standing caveat).  This is
    the term the width cap actually moves (Eq-3 traffic is cap-invariant),
    so the autotune sweep scores with it.  Value slots are priced at the
    operand itemsize, column-index slots always at ``INDEX_BYTES``."""
    vals = float(dsched.ell_cols1.size + dsched.spill_rows1.size)
    idx = float(dsched.ell_cols1.size
                + (cost_model.SPILL_ELEMENTS - 1) * dsched.spill_rows1.size)
    if b_is_sparse:
        # one arithmetic, owned by cost_model (a.n_cols = no-cap sentinel:
        # no row can be wider, so the clamp resolves it to pad-to-max)
        w = cost_model._capped_body_width(
            a, dsched.width_cap if dsched.width_cap is not None
            else max(a.n_cols, 1))
        spill = int(cost_model._spill_cumsum(a, w)[-1])
        vals += float(a.n_rows * w + spill)
        idx += float(a.n_rows * w + (cost_model.SPILL_ELEMENTS - 1) * spill)
    return vals * dtype_bytes + idx * cost_model.INDEX_BYTES


def get_schedule(a: CSR, *, b_col: int, c_col: int,
                 b_is_sparse: bool = False,
                 spec: FusionSpec | None = None, **legacy) -> ScheduleEntry:
    """Run Algorithm 1 once per (content, resolved spec) and memoize;
    subsequent calls with the same key return the cached entry without
    touching the scheduler.

    Every knob lives on the ``FusionSpec`` (``spec=``); the historical
    keyword surface (``p=``, ``ct_size=``, ``mesh=``, ...) still works as
    a deprecation shim that builds the spec and warns once per process.
    ``spec.dtype_bytes=None`` defaults to 4 here — without operands there
    is nothing to infer from (``tile_fused_matmul`` infers before it
    reaches this point).

    Note: ``spec.uniform_split`` defaults to True (unlike raw
    ``build_schedule``) — the uniform variant is what the zero-padding XLA
    fast path and the Pallas kernel's grid map 1:1 onto.  Call sites that
    want the paper's recursive step-2 splitting set it explicitly.

    ``spec.autotune=True`` replaces the single inspection with a memoized
    Eq-3 sweep over tile sizes, cache budgets, and hybrid width caps (see
    module docs); the spec's own ``ct_size`` / ``cache_size`` /
    ``width_cap`` then seed the candidate grid instead of being used
    verbatim.

    ``spec.width_cap`` bounds the hybrid-ELL body width (wavefront 1
    always; op-1 packing and Eq-3 op-1 pricing when ``b_is_sparse``):
    ``"auto"`` (default) picks the traffic-optimal cap from the degree
    distribution, ``None`` disables capping (pad-to-max).  The resolved
    cap is part of the cache key — changing it can never reuse a stale
    schedule.

    ``spec.mesh`` (a ``jax.sharding.Mesh``) additionally partitions the
    wavefront-0 tile grid over the mesh's devices (row-block,
    Eq-3-balanced) and attaches the per-shard arrays + halo index sets as
    ``entry.shard``.  ``spec.shard_layout``
    ("auto" | "1d" | "1.5d" | "2.5d") picks how a multi-axis mesh's axes
    are used, ``spec.shard_combine`` ("auto" | "psum" | "reduce_scatter")
    the output combine, ``spec.overlap`` whether the wavefront-1 halo
    gather hides under wavefront-0 compute, and ``spec.n_repl`` the
    required operand-replication factor; all join the cache key alongside
    the mesh's (axis names, shape): the same matrix on a different mesh
    shape or layout re-inspects.  A trivial (single-device or None) mesh
    keys and dispatches exactly like no mesh — the then-inert shard knobs
    collapse out of the key.  When ``"auto"`` layout pricing concludes
    even the best mesh layout moves more bytes than single-device
    execution, ``entry.shard`` stays None (the priced fallback).

    ``spec.bucket`` (the serving tier's knob — see ``serving.ServingTier``)
    replaces the content digest in the cache key with the given shape
    bucket, so every request padded into the same bucket shares one
    entry instead of each pattern minting its own.  Because the key no
    longer pins the content, a hit is only trusted when the entry's
    ``content_digest`` matches the request (the tier keeps it current via
    ``store_bucket_schedule``); a mismatch re-inspects and *replaces* the
    entry under the same key — never a second cache slot, so N patterns
    in one bucket occupy exactly one entry.  v1 is single-device:
    ``bucket`` with ``autotune`` or a non-trivial ``mesh`` raises.

    ``spec.transpose=True`` inspects ``a.transpose()`` instead — the
    backward pass's schedule.  The key stays on the *forward* matrix's
    digest plus the transpose bit, so the fwd/bwd pair of one training
    step shares one digest computation and shows up side by side in the
    cache (``schedule_cache_stats()["transpose_entries"]``).  ``b_col`` /
    ``c_col`` are the dimensions of the transposed product — the caller
    passes them already swapped.

    ``spec.dtype_bytes`` is the dense operand's itemsize; it scales the
    Eq-3 value traffic (index traffic stays at 4 bytes) and joins the
    cache key so bf16 and f32 runs of one pattern price — and autotune —
    separately.

    ``spec.reorder`` makes bandwidth-reducing reordering a schedule
    transform: the pattern is symmetrically permuted (RCM or the
    similarity grouping; ``"auto"`` tries both) before inspection, the
    candidate priced by the same Eq-3 model as dispatch, and — when it
    applies — the permutation baked into the entry
    (``reorder_perm``/``reorder_inv``); ``tile_fused_matmul`` permutes
    the dense operands in and the output back out, so callers never see
    the reordered frame.  ``"auto"`` applies only when the modeled fused
    traffic beats the identity by ``MIN_TRAFFIC_SAVING`` and skips
    rectangular patterns; a forced ordering raises on them.  The knob
    joins the cache key (``_spec_key``); it does not compose with
    ``bucket``."""
    spec = _coerce_spec(spec, legacy, "get_schedule")
    if spec.dtype_bytes is None:
        spec = dataclasses.replace(spec, dtype_bytes=4)
    else:
        spec = dataclasses.replace(spec, dtype_bytes=int(spec.dtype_bytes))
    transpose = spec.transpose
    a_eff = a.transpose() if transpose else a
    cap = _resolve_width_cap(a_eff, spec.width_cap)
    mk = sharded.mesh_key(spec.mesh)
    sk = _shard_knobs_key(mk, spec.shard_combine, spec.shard_layout)
    bucket = spec.bucket
    if bucket is not None:
        if spec.autotune:
            raise ValueError("bucket= does not compose with autotune=True "
                             "(the sweep is per-content; bucket entries "
                             "are shape-keyed)")
        if mk is not None:
            raise ValueError("bucket= is single-device (v1); pass a "
                             "trivial mesh or none")
        if transpose:
            raise ValueError("bucket= is a serving (inference) knob; it "
                             "does not compose with transpose=True")
        if spec.reorder is not None:
            raise ValueError("bucket= does not compose with reorder= — "
                             "the incremental inspector patches by row "
                             "position, which a baked permutation would "
                             "silently invalidate")
    if spec.autotune:
        return _autotune_schedule(a, b_col=b_col, c_col=c_col,
                                  b_is_sparse=b_is_sparse, spec=spec,
                                  cap=cap, mk=mk, sk=sk)
    digest = _content_key(a)
    keybase = ("bucket", bucket) if bucket is not None else digest
    key = (keybase, b_col, c_col, b_is_sparse,
           _spec_key(spec, cap=cap, mk=mk, sk=sk))
    with _lock:
        entry = _cache_get(_schedule_cache, key)
        if entry is not None and (bucket is None
                                  or entry.content_digest == digest):
            entry.hits += 1
            _stats["hits"] += 1
            return entry
    t0 = time.perf_counter()
    sched = build_schedule(a_eff, b_col=b_col, c_col=c_col, p=spec.p,
                           cache_size=spec.cache_size, ct_size=spec.ct_size,
                           b_is_sparse=b_is_sparse,
                           uniform_split=spec.uniform_split, width_cap=cap)
    dsched = to_device_schedule(a_eff, sched, width_cap=cap)
    tm = dsched.hbm_traffic_model(b_col, c_col,
                                  dtype_bytes=spec.dtype_bytes)
    a_sched = a_eff
    applied = perm = inv = None
    if spec.reorder is not None:
        picked = _priced_reorder(a_eff, spec, cap=cap, b_col=b_col,
                                 c_col=c_col, b_is_sparse=b_is_sparse,
                                 base_tm=tm)
        if picked is not None:
            applied, perm, inv, a_sched, sched, dsched, tm = picked
    tm["packed_ell_bytes"] = _packed_ell_bytes(a_sched, dsched, b_is_sparse,
                                               spec.dtype_bytes)
    shard = None
    if mk is not None:
        shard = _shard_for_mesh(a_sched, sched, dsched, mk, b_col=b_col,
                                c_col=c_col, b_is_sparse=b_is_sparse,
                                width_cap=cap, shard_combine=sk[0],
                                shard_layout=sk[1],
                                dtype_bytes=spec.dtype_bytes,
                                overlap=spec.overlap, n_repl=spec.n_repl,
                                serial_bytes=tm["fused_bytes"])
        if shard is not None:
            tm["sharded"] = shard.comm_model
    entry = ScheduleEntry(sched=sched, dsched=dsched, b_col=b_col,
                          c_col=c_col, b_is_sparse=b_is_sparse,
                          inspector_s=time.perf_counter() - t0,
                          traffic_model=tm, width_cap=cap,
                          mesh_key=mk, shard=shard,
                          content_digest=digest,
                          bucket=bucket,
                          transpose=transpose,
                          dtype_bytes=spec.dtype_bytes,
                          reorder=applied, reorder_perm=perm,
                          reorder_inv=inv)
    with _lock:
        _stats["misses"] += 1
        _cache_put(_schedule_cache, key, entry)
    return entry


def _priced_reorder(a_eff: CSR, spec: FusionSpec, *, cap, b_col: int,
                    c_col: int, b_is_sparse: bool, base_tm: dict):
    """Resolve ``spec.reorder`` into an applied schedule transform.

    Builds a full candidate schedule per ordering (RCM, or the
    binary-row-merging similarity grouping; ``"auto"`` tries both) on the
    symmetrically permuted pattern and prices it with the same Eq-3
    tile-cost aggregation the dispatch floor uses (``fused_bytes`` is the
    ``tile_costs_batch`` sum).  A forced ordering always applies; "auto"
    applies the best candidate only when its modeled fused traffic beats
    the identity ordering by ``MIN_TRAFFIC_SAVING`` — the same
    bytes-model-vs-off-model-fixed-costs floor ``select_backend`` trusts —
    so "auto" can never raise modeled traffic.  Returns ``(name, perm,
    inv, a_perm, sched, dsched, tm)`` or None for the identity.

    The symmetric permutation P·A·Pᵀ needs a square matrix; "auto" skips
    rectangular patterns quietly, a forced ordering raises (the old
    ``permute_csr`` silently corrupted this case)."""
    if a_eff.n_rows != a_eff.n_cols:
        if spec.reorder == "auto":
            return None
        raise ValueError(
            f"reorder={spec.reorder!r} needs a square matrix (symmetric "
            f"permutation P·A·Pᵀ); got ({a_eff.n_rows}, {a_eff.n_cols}). "
            f"Use reorder='auto' to skip rectangular patterns.")
    names = (("rcm", "similarity") if spec.reorder == "auto"
             else (spec.reorder,))
    best = None
    for name in names:
        fn = reorder.rcm_order if name == "rcm" else reorder.similarity_order
        cand_perm = fn(a_eff)
        a_p = reorder.permute_csr(a_eff, cand_perm)
        sched_p = build_schedule(a_p, b_col=b_col, c_col=c_col, p=spec.p,
                                 cache_size=spec.cache_size,
                                 ct_size=spec.ct_size,
                                 b_is_sparse=b_is_sparse,
                                 uniform_split=spec.uniform_split,
                                 width_cap=cap)
        dsched_p = to_device_schedule(a_p, sched_p, width_cap=cap)
        tm_p = dsched_p.hbm_traffic_model(b_col, c_col,
                                          dtype_bytes=spec.dtype_bytes)
        if best is None or tm_p["fused_bytes"] < best[5]["fused_bytes"]:
            best = (name, cand_perm, a_p, sched_p, dsched_p, tm_p)
    name, cand_perm, a_p, sched_p, dsched_p, tm_p = best
    if (spec.reorder == "auto"
            and cost_model.reorder_gain(base_tm, tm_p) < MIN_TRAFFIC_SAVING):
        return None
    inv = np.empty_like(cand_perm)
    inv[cand_perm] = np.arange(cand_perm.shape[0])
    return name, cand_perm, inv, a_p, sched_p, dsched_p, tm_p


def store_bucket_schedule(entry: ScheduleEntry, *, bucket: tuple,
                          patched: bool = False,
                          spec: FusionSpec | None = None,
                          **legacy) -> ScheduleEntry:
    """Publish a serving-tier entry (headroom-padded at bucket build, or
    patched by the incremental inspector) under its bucket cache key,
    replacing whatever the bucket held.

    The key is cut by the same ``_spec_key`` helper ``get_schedule`` uses
    (bucket keybase, the entry's own resolved width cap, trivial-mesh
    collapse, transpose forced off — buckets are inference-only), so the
    next ``tile_fused_matmul(..., spec=...bucket...)`` dispatch finds this
    entry; ``entry.content_digest`` must already name the pattern it
    serves.  ``patched=True`` counts the publish as an incremental patch
    in ``schedule_cache_stats()``."""
    if entry.content_digest is None:
        raise ValueError("bucket entries need content_digest set")
    spec = _coerce_spec(spec, legacy, "store_bucket_schedule")
    spec = dataclasses.replace(
        spec, transpose=False, mesh=None, reorder=None,
        dtype_bytes=4 if spec.dtype_bytes is None else int(spec.dtype_bytes))
    key = (("bucket", tuple(bucket)), entry.b_col, entry.c_col,
           entry.b_is_sparse,
           _spec_key(spec, cap=entry.width_cap, mk=None, sk=(None, None)))
    entry.bucket = tuple(bucket)
    with _lock:
        if patched:
            _stats["incremental_patches"] += 1
        _cache_put(_schedule_cache, key, entry)
    return entry


def _autotune_schedule(a: CSR, *, b_col: int, c_col: int,
                       b_is_sparse: bool, spec: FusionSpec, cap: int | None,
                       mk: tuple | None, sk: tuple) -> ScheduleEntry:
    """Eq-3 tile-size × width-cap sweep, memoized under its own entry.

    Candidates: (AUTOTUNE_CT_GRID ∪ {spec.ct_size, 2048}) ×
    AUTOTUNE_CACHE_SCALES × candidate width caps
    (``_candidate_width_caps``).  Ranking: Eq-3 predicted fast-memory
    traffic (``fused_bytes``) scaled by the schedule's padded-FLOPs
    overhead, plus the packed-ELL bytes the cap actually moves; restricted
    to candidates whose raw traffic does not exceed the default
    ``ct_size=2048`` schedule's at the caller's cap — the anchor itself is
    always a candidate, so the sweep can only improve on the paper's
    heuristic, never regress it.

    ``cap`` / ``mk`` / ``sk`` are the caller-resolved width cap, mesh key,
    and shard-knob pair; the key is the same ``_spec_key`` tail as every
    other cache site, under the "autotune" prefix.
    """
    transpose = spec.transpose
    cache_size = spec.cache_size
    key = ("autotune", _content_key(a), b_col, c_col, b_is_sparse,
           _spec_key(spec, cap=cap, mk=mk, sk=sk))
    with _lock:
        entry = _cache_get(_schedule_cache, key)
        if entry is not None:
            entry.hits += 1
            _stats["hits"] += 1
            return entry

    t0 = time.perf_counter()
    a_eff = a.transpose() if transpose else a
    cts = sorted(set(AUTOTUNE_CT_GRID) | {spec.ct_size, DEFAULT_CT_SIZE})
    if cap is None:
        # pad-to-max resolves to the max-degree cap so keys stay concrete
        counts = np.diff(a_eff.indptr)
        anchor_cap = max(int(counts.max()), 1) if counts.size else 1
    else:
        anchor_cap = cap
    # the cap only reaches Algorithm 1 through the sparse-op-1 Eq-3 charge;
    # for dense B every cap yields the identical host schedule, so sweeping
    # caps there would just re-run the same inspection — keep the caller's
    caps = _candidate_width_caps(a_eff, cap) if b_is_sparse \
        else [anchor_cap]
    candidates: dict = {}
    for ct in cts:
        for scale in AUTOTUNE_CACHE_SCALES:
            for cand_cap in caps:
                cand_spec = dataclasses.replace(
                    spec, autotune=False, cache_size=cache_size * scale,
                    ct_size=ct, width_cap=cand_cap, mesh=None)
                cand = get_schedule(a, b_col=b_col, c_col=c_col,
                                    b_is_sparse=b_is_sparse,
                                    spec=cand_spec)
                candidates[(ct, cache_size * scale, cand_cap)] = cand

    def traffic(e: ScheduleEntry) -> float:
        return e.traffic_model["fused_bytes"]

    def score(e: ScheduleEntry) -> float:
        return (traffic(e)
                * (1.0 + e.dsched.padded_flops_overhead(b_col, c_col))
                + e.traffic_model["packed_ell_bytes"])

    anchor = candidates[(DEFAULT_CT_SIZE, cache_size, anchor_cap)]
    eligible = {k: e for k, e in candidates.items()
                if traffic(e) <= traffic(anchor)}
    best_key = min(eligible, key=lambda k: score(eligible[k]))
    # the autotuned entry's inspection cost is the whole sweep (what a
    # fig10-style amortization argument must pay off), not one candidate
    best = dataclasses.replace(eligible[best_key], hits=0,
                               autotuned=best_key,
                               inspector_s=time.perf_counter() - t0)
    if mk is not None:
        # the sweep's candidates are mesh-free; shard the winner (a fresh
        # traffic_model dict so the single-device candidate stays untouched).
        # A reordered winner must be sharded on the *permuted* matrix its
        # schedule was inspected under, not the caller's ordering.
        a_shard = (reorder.permute_csr(a_eff, best.reorder_perm)
                   if best.reorder_perm is not None else a_eff)
        shard = _shard_for_mesh(a_shard, best.sched, best.dsched, mk,
                                b_col=b_col, c_col=c_col,
                                b_is_sparse=b_is_sparse,
                                width_cap=best.width_cap,
                                shard_combine=sk[0],
                                shard_layout=sk[1],
                                dtype_bytes=spec.dtype_bytes,
                                overlap=spec.overlap, n_repl=spec.n_repl,
                                serial_bytes=best.traffic_model[
                                    "fused_bytes"])
        tm = dict(best.traffic_model)
        if shard is not None:
            tm["sharded"] = shard.comm_model
        best = dataclasses.replace(best, mesh_key=mk, shard=shard,
                                   traffic_model=tm)
    with _lock:
        # first-wins publish: a concurrent sweep on the same key may have
        # finished while we ran (the candidates it used were memoized, so
        # the duplicate work is bounded); only the published sweep counts
        existing = _cache_get(_schedule_cache, key)
        if existing is not None:
            existing.hits += 1
            _stats["hits"] += 1
            return existing
        _stats["autotune_sweeps"] += 1
        _cache_put(_schedule_cache, key, best)
    return best


def _csr_ell(a: CSR, width_cap: int | None = None) -> Tuple[jax.Array, ...]:
    """Memoized full-matrix hybrid ELL (the unfused executor's format),
    keyed on (content, width cap).

    Check-and-insert happens under a single ``_ell_lock`` acquisition: the
    previous read-then-write pattern let two threads race past the miss
    check and both build (and publish) the ELL arrays.  The dedicated lock
    means a large build never blocks schedule-cache hits.

    The build runs under ``jax.ensure_compile_time_eval()``: a miss can
    happen inside a trace (the custom_vjp backward builds the Aᵀ ELL while
    ``jax.grad`` traces), and ``jnp.asarray`` under an active trace yields
    a *tracer* — caching that would poison every later trace with a leaked
    value.  The guard forces concrete arrays no matter where the miss
    lands."""
    key = (_content_key(a), width_cap)
    with _ell_lock:
        ell = _cache_get(_ell_cache, key)
        if ell is None:
            with jax.ensure_compile_time_eval():
                ell = fused_ops.csr_to_ell(a, width_cap=width_cap)
            _cache_put(_ell_cache, key, ell, evict_key="ell_evictions")
    return ell


def clear_schedule_cache() -> None:
    with _lock, _ell_lock:
        _schedule_cache.clear()
        _ell_cache.clear()
        for k in _stats:
            _stats[k] = 0
    # re-arm the once-per-process legacy-kwargs deprecation warning so
    # warning tests stay order-independent across the suite
    reset_legacy_warning()


def schedule_cache_stats() -> dict:
    """Counters plus live entry counts of both process-wide caches.
    ``mesh_entries`` counts the live schedule entries inspected for a
    non-trivial mesh (the sharded-dispatch tier's cache footprint), broken
    down by the layout the dispatch resolved: ``layout_1d`` (pure row
    shards), ``layout_15d`` (column-replicated 1.5D), ``layout_25d``
    (depth-replicated 2.5D), ``layout_fallback`` (mesh-keyed entries that
    dispatch single-device — non-uniform grids, or layouts the chooser
    priced worse than serial).  ``spec_entries`` counts the distinct
    resolved ``FusionSpec`` key tails among live schedule entries — how
    many knob combinations the process actually runs (N matrices under
    one spec keep it at 1).  ``bucket_entries`` counts the live
    shape-bucket entries of the serving tier — N patterns mapping to K
    buckets should hold this (and evictions) at K, the LRU-thrash
    regression the serving tests pin.  ``transpose_entries`` counts the
    live backward-pass (``transpose=True``) schedules the custom_vjp
    training path inspected — one per (graph, shape) when the transpose
    cache amortizes correctly."""
    with _lock, _ell_lock:
        mesh_entries = layout_1d = layout_15d = layout_25d = 0
        layout_fallback = bucket_entries = transpose_entries = 0
        reorder_entries = 0
        for e in _schedule_cache.values():
            if e.bucket is not None:
                bucket_entries += 1
            if e.transpose:
                transpose_entries += 1
            if e.reorder is not None:
                reorder_entries += 1
            if e.mesh_key is None:
                continue
            mesh_entries += 1
            if e.shard is None:
                layout_fallback += 1
            elif e.shard.layout == "2.5d":
                layout_25d += 1
            elif e.shard.layout == "1.5d":
                layout_15d += 1
            else:
                layout_1d += 1
        # every schedule-cache key ends in the resolved-spec tail
        # (_spec_key), for both content and "autotune"-prefixed keys
        spec_entries = len({k[-1] for k in _schedule_cache})
        return dict(_stats, entries=len(_schedule_cache),
                    ell_entries=len(_ell_cache),
                    mesh_entries=mesh_entries,
                    bucket_entries=bucket_entries,
                    transpose_entries=transpose_entries,
                    reorder_entries=reorder_entries,
                    spec_entries=spec_entries,
                    layout_1d=layout_1d, layout_15d=layout_15d,
                    layout_25d=layout_25d,
                    layout_fallback=layout_fallback)


# --------------------------------------------------------------------------
# Backend selection (Eq-3 cost model + capability checks)
# --------------------------------------------------------------------------
def _pallas_capable() -> bool:
    """Capability gate shared by the GeMM-SpMM and SpMM-SpMM Pallas arms;
    the logic lives with the kernels' own mode resolution
    (``kernels.config``) so dispatch and execution can never disagree."""
    from ...kernels.config import compiled_or_forced
    return compiled_or_forced()


def _spmm_pallas_fits_vmem(entry: ScheduleEntry, c_col: int) -> bool:
    """SpMM-SpMM kernel VMEM feasibility: the kernel stages all of C plus a
    ``(t, n)`` one-hot per grid step, which scales with the *problem* size
    (unlike the GeMM kernel, whose blocks scale only with t).  Auto
    dispatch must fall back to the XLA executor above the budget instead
    of handing Mosaic an unallocatable kernel."""
    from ...kernels.ops import VMEM_BUDGET
    ds = entry.dsched
    t, n = ds.t_pad, ds.n_i
    j0 = ds.j_rows0.shape[1]
    w0 = ds.ell_cols0.shape[2]
    w1 = ds.width_cap if ds.width_cap is not None else n
    elems = (n * c_col          # C staged in full
             + t * n            # op-1 one-hot w1_mat
             + 2 * t * c_col    # D1 tile + spill block
             + 2 * t * w1       # op-1 ELL body
             + 2 * j0 * w0      # fused-rows ELL
             + j0 * t           # densified A tile
             + j0 * c_col)      # fused rows out
    return elems * entry.dtype_bytes <= VMEM_BUDGET


def select_backend(entry: ScheduleEntry) -> str:
    """Resolve ``backend="auto"`` for an inspected schedule."""
    tm = entry.traffic_model
    if entry.shard is not None:
        # the entry was inspected for a non-trivial mesh (>1 device) and the
        # grid partitioned; honoring the mesh outranks every local backend,
        # including the unfused fallback — even a fusion-free schedule still
        # distributes op-1 rows and wavefront-1 work across the devices
        return "sharded"
    if (entry.sched.fused_ratio < MIN_FUSED_RATIO
            or tm["traffic_saving"] <= MIN_TRAFFIC_SAVING):
        # fusion saves no traffic (or too little to cover the tile loop's
        # off-model fixed costs) — Eq 3 says the intermediate round-trips
        # memory either way, so take the simpler code
        return "unfused"
    if fused_ops._is_uniform(entry.dsched) and _pallas_capable():
        # both op pairs lower to wavefront-0 Pallas kernels on a uniform
        # grid (GeMM-SpMM and, via the hybrid op-1 gather, SpMM-SpMM)
        if not entry.b_is_sparse:
            return "pallas"
        if _spmm_pallas_fits_vmem(entry, entry.c_col):
            return "pallas"
    return "xla"


def _require_uniform(ds: DeviceSchedule) -> None:
    if not fused_ops._is_uniform(ds):
        raise ValueError(
            "backend='pallas' needs a uniform schedule; inspect with "
            "uniform_split=True (the default) or use backend='xla'")


def _wf1_pallas(ds: DeviceSchedule, d: jax.Array, d1: jax.Array,
                dtype) -> jax.Array:
    """Post-barrier wavefront 1 for the Pallas paths: hybrid ELL body via
    the Pallas SpMM kernel over the completed D1, then the spill lanes
    (hub-row tails past the width cap) as one scatter-add."""
    from ...kernels import ops as kops
    c_col = d.shape[1]
    if ds.j_rows1.size:
        t1, j1, w1 = ds.ell_cols1.shape
        rows1 = kops.spmm_ell(
            jnp.asarray(ds.ell_cols1.reshape(t1 * j1, w1)),
            jnp.asarray(ds.ell_vals1.reshape(t1 * j1, w1), dtype), d1)
        d = d.at[ds.j_rows1.reshape(-1)].set(rows1.reshape(-1, c_col),
                                             mode="drop")
    if ds.spill_rows1.size:
        d = d.at[jnp.asarray(ds.spill_rows1)].add(
            jnp.asarray(ds.spill_vals1, dtype)[:, None]
            * d1[jnp.asarray(ds.spill_cols1)])
    return d


def _gemm_spmm_pallas(entry: ScheduleEntry, b: jax.Array,
                      c: jax.Array) -> jax.Array:
    """Wavefront 0 through the Pallas kernel, wavefront 1 via the ELL SpMM
    kernel over the spilled D1 — the pallas_call boundary is the barrier."""
    from ...kernels import ops as kops
    ds = entry.dsched
    _require_uniform(ds)
    t, n_t = ds.t_pad, ds.n_tiles0
    if b.shape[0] != ds.n_i:
        raise ValueError(f"b has {b.shape[0]} rows, schedule expects {ds.n_i}")
    b_pad = jnp.pad(b, ((0, n_t * t - b.shape[0]), (0, 0)))
    d1, rows0 = kops.tile_fused_gemm_spmm_wf0(
        jnp.asarray(ds.ell_cols0), jnp.asarray(ds.ell_vals0, b.dtype),
        b_pad, c, t=t)
    c_col = c.shape[1]
    d = jnp.zeros((ds.n_j, c_col), b.dtype).at[
        ds.j_rows0.reshape(-1)].set(rows0.reshape(-1, c_col), mode="drop")
    return _wf1_pallas(ds, d, d1[: ds.n_i], b.dtype)


def _spmm_spmm_pallas(entry: ScheduleEntry, a1: CSR,
                      c: jax.Array) -> jax.Array:
    """SpMM-SpMM wavefront 0 through the Pallas kernel: hybrid op-1 ELL
    (shared packer, spill pre-accumulated outside the kernel) feeds the
    tile-local second SpMM; wavefront 1 runs over the spilled D1."""
    from ...kernels import ops as kops
    ds = entry.dsched
    _require_uniform(ds)
    t, n_t = ds.t_pad, ds.n_tiles0
    if a1.n_rows != ds.n_i:
        raise ValueError(
            f"op-1 has {a1.n_rows} rows, schedule expects {ds.n_i}")
    if c.shape[0] != a1.n_cols:
        raise ValueError(
            f"c has {c.shape[0]} rows, op-1 has {a1.n_cols} columns")
    c_col = c.shape[1]
    o_cols, o_vals, spill_flat, spill_cols, spill_vals = fused_ops._op1_ell(
        a1, ds, width_cap=ds.width_cap)
    d1_spill = jnp.zeros((n_t * t, c_col), c.dtype)
    if spill_flat.size:
        d1_spill = d1_spill.at[jnp.asarray(spill_flat)].add(
            jnp.asarray(spill_vals, c.dtype)[:, None]
            * c[jnp.asarray(spill_cols)])
    d1, rows0 = kops.tile_fused_spmm_spmm_wf0(
        jnp.asarray(o_cols), jnp.asarray(o_vals, c.dtype), d1_spill,
        jnp.asarray(ds.ell_cols0), jnp.asarray(ds.ell_vals0, c.dtype),
        c, t=t)
    d = jnp.zeros((ds.n_j, c_col), c.dtype).at[
        ds.j_rows0.reshape(-1)].set(rows0.reshape(-1, c_col), mode="drop")
    return _wf1_pallas(ds, d, d1[: ds.n_i], c.dtype)


# --------------------------------------------------------------------------
# The entrypoint
# --------------------------------------------------------------------------
def _dispatch(a: CSR, b_or_a1, c, *, backend: str,
              spec: FusionSpec) -> jax.Array:
    """The schedule-then-execute tail of ``tile_fused_matmul`` — everything
    past the custom_vjp seam.  ``spec.transpose=True`` runs the product
    with all sparse operands transposed (``D = aᵀ·(bᵀ·c)`` structurally —
    for the GeMM-SpMM pair only ``a`` is sparse, so ``D = aᵀ·(b·c)``),
    serving the backward pass from the transpose-keyed schedule entry."""
    b_is_sparse = isinstance(b_or_a1, CSR)
    transpose = spec.transpose
    width_cap = spec.width_cap
    a_run = a.transpose() if transpose else a
    a1_run = (b_or_a1.transpose() if (b_is_sparse and transpose)
              else b_or_a1)

    def run_unfused():
        if b_is_sparse:
            hell_a = _csr_ell(a_run, _resolve_width_cap(a_run, width_cap))
            hell_a1 = _csr_ell(a1_run,
                               _resolve_width_cap(a1_run, width_cap))
            return fused_ops.unfused_spmm_spmm(*hell_a, *hell_a1, c)
        return fused_ops.unfused_gemm_spmm(
            *_csr_ell(a_run, _resolve_width_cap(a_run, width_cap)),
            jnp.asarray(b_or_a1), c)

    if backend == "unfused":
        return run_unfused()          # no inspection needed for the baseline

    # the cost model's b_col is the width of the intermediate D1's inputs:
    # dense-B column count for GeMM-SpMM, C's column count for SpMM-SpMM
    # (op 1 is a1 @ c, so D1 is c_col wide and B's dense charge is c_col)
    b_col = c.shape[1] if b_is_sparse else b_or_a1.shape[1]
    if spec.dtype_bytes is None:
        spec = dataclasses.replace(spec, dtype_bytes=(
            cost_model.operand_dtype_bytes(c if b_is_sparse else b_or_a1,
                                           c)))
    entry = get_schedule(a, b_col=b_col, c_col=c.shape[1],
                         b_is_sparse=b_is_sparse, spec=spec)
    chosen = select_backend(entry) if backend == "auto" else backend

    if chosen == "sharded" and entry.shard is None:
        # trivial mesh, a non-uniform grid, or the priced single-device
        # fallback: the XLA executor is the sharded path's one-device twin
        chosen = "xla"
    if chosen == "unfused":
        return run_unfused()          # unpermuted operands — no reorder math
    # an entry built under spec.reorder carries its permutation: permute
    # the row-indexed operands in (P·B / P·A1 — jnp.take, so gradients
    # flow through the linear permutation) and the output back out; the
    # caller never sees the reordered frame
    perm = entry.reorder_perm
    if perm is not None:
        if b_is_sparse:
            a1_run = reorder.permute_rows_cached(a1_run, perm)
    if chosen == "sharded":
        if b_is_sparse:
            d = sharded.sharded_spmm_spmm(entry.shard, entry.dsched,
                                          spec.mesh, a1_run, c)
        else:
            b = jnp.asarray(b_or_a1)
            if perm is not None:
                b = jnp.take(b, jnp.asarray(perm), axis=0)
            d = sharded.sharded_gemm_spmm(entry.shard, spec.mesh, b, c)
    elif b_is_sparse:
        if chosen == "pallas":
            d = _spmm_spmm_pallas(entry, a1_run, c)
        else:
            d = fused_ops.fused_spmm_spmm(entry.dsched, a1_run, c)
    else:
        b = jnp.asarray(b_or_a1)
        if perm is not None:
            b = jnp.take(b, jnp.asarray(perm), axis=0)
        if chosen == "pallas":
            d = _gemm_spmm_pallas(entry, b, c)
        else:
            d = fused_ops.fused_gemm_spmm(entry.dsched, b, c)
    if perm is not None:
        d = jnp.take(d, jnp.asarray(entry.reorder_inv), axis=0)
    return d


def _bwd_knobs(knobs: dict) -> dict:
    """Knob set for the backward dispatch: the spec flips its transpose
    bit (so the backward of an already-transposed product runs on the
    *forward* schedule — (Aᵀ)ᵀ = A), and the serving ``bucket`` — an
    inference-only shape key — never leaks into training entries.
    Everything else (backend, mesh, tile knobs) carries over so the
    backward lands on the same Eq-3 ``select_backend`` seam."""
    spec = knobs["spec"]
    return dict(backend=knobs["backend"],
                spec=dataclasses.replace(spec, transpose=not spec.transpose,
                                         bucket=None))


def _transpose_spmm(a: CSR, x: jax.Array, *, transpose: bool,
                    width_cap) -> jax.Array:
    """Plain ``Aᵀ·x`` (or ``A·x`` when the forward was transposed) — the
    second sparse product of the GeMM-SpMM backward, served from the same
    content-keyed full-matrix hybrid-ELL cache the unfused executor uses."""
    a_eff = a.transpose() if transpose else a
    return fused_ops.spmm_hybrid(
        *_csr_ell(a_eff, _resolve_width_cap(a_eff, width_cap)), x)


def _gemm_spmm_diff(a: CSR, knobs: dict):
    """custom_vjp wrapper for the GeMM-SpMM pair (``D = A·(B·C)``).

    The CSR and the dispatch knobs are closed over (a frozen dataclass of
    ndarrays can't ride through ``nondiff_argnums``, which wants hashable
    statics); only the dense operands are traced.  Backward: the two
    transposed sparse-dense products —

      ``dB = Aᵀ·(Ḋ·Cᵀ)``  (a fused GeMM-SpMM against Aᵀ, dispatched
      through ``tile_fused_matmul`` with the transpose bit flipped, so it
      hits the cached transpose schedule and the same backend selection),
      ``dC = Bᵀ·(Aᵀ·Ḋ)``  (one plain SpMM against Aᵀ, then a dense GeMM).
    """
    def primal(b, c):
        return _dispatch(a, b, c, **knobs)

    def fwd(b, c):
        return primal(b, c), (b, c)

    def bwd(res, dd):
        b, c = res
        bk = _bwd_knobs(knobs)
        db = tile_fused_matmul(a, dd, c.T, **bk)
        g1 = _transpose_spmm(a, dd, transpose=bk["spec"].transpose,
                             width_cap=knobs["spec"].width_cap)
        dc = b.T.astype(g1.dtype) @ g1
        return jnp.asarray(db, b.dtype), jnp.asarray(dc, c.dtype)

    f = jax.custom_vjp(primal)
    f.defvjp(fwd, bwd)
    return f


def _spmm_spmm_diff(a: CSR, a1: CSR, knobs: dict):
    """custom_vjp wrapper for the SpMM-SpMM pair (``D = A·(A1·C)``).

    Only the dense ``C`` differentiates (the sparse operands are host
    CSRs, not traced values).  Its cotangent is itself a fused SpMM-SpMM
    with the operand roles swapped — ``dC = A1ᵀ·(Aᵀ·Ḋ)`` — dispatched
    back through ``tile_fused_matmul`` with the transpose bit flipped, so
    the backward runs the same two-wavefront schedule machinery against
    the cached transpose entries."""
    def primal(c):
        return _dispatch(a, a1, c, **knobs)

    def fwd(c):
        return primal(c), None

    def bwd(_, dd):
        dc = tile_fused_matmul(a1, a, dd, **_bwd_knobs(knobs))
        return (jnp.asarray(dc, dd.dtype),)

    f = jax.custom_vjp(primal)
    f.defvjp(fwd, bwd)
    return f


def tile_fused_matmul(a: CSR, b_or_a1, c, *, backend: str = "auto",
                      spec: FusionSpec | None = None, **legacy) -> jax.Array:
    """``D = a @ (b_or_a1 @ c)`` through the tile-fusion schedule.

    Args:
      a: CSR matrix of the second (consumer) operation.
      b_or_a1: dense ``(n_i, b_col)`` array → GeMM-SpMM, or a ``CSR`` →
        SpMM-SpMM (op-1 rows gathered per tile).
      c: dense ``(b_col, c_col)`` (GeMM-SpMM) / ``(n, c_col)`` (SpMM-SpMM).
      backend: "auto" (Eq-3 cost model + capability), or an explicit
        "pallas" / "xla" / "unfused" / "sharded" override for benchmarks.
        Both op pairs lower to "pallas" (SpMM-SpMM via the hybrid op-1
        gather) and to "sharded" (shard_map over ``spec.mesh``).
      spec: a ``FusionSpec`` carrying every other knob — Algorithm-1 tile
        parameters (``p``, ``cache_size``, ``ct_size``,
        ``uniform_split``), the ``autotune`` sweep, the hybrid-ELL
        ``width_cap``, distribution (``mesh``, ``shard_combine``,
        ``shard_layout``, ``overlap``, ``n_repl``), the serving
        ``bucket``, the backward-pass ``transpose`` bit, and
        ``dtype_bytes`` (None = inferred from the dense operands here).
        ``None`` means the default spec.  See ``spec.FusionSpec`` and
        ``get_schedule`` for per-knob semantics; the resolved spec is the
        schedule-cache key.
      **legacy: the historical keyword surface (``p=``, ``ct_size=``,
        ``mesh=``, ...) — a deprecation shim that builds the spec for you
        and warns once per process.  Mixing ``spec=`` with legacy
        keywords raises.

    Distribution notes: ``spec.mesh`` partitions the wavefront-0 tile
    grid row-block across the mesh's row shards (Eq-3-balanced);
    wavefront 1 reads an all-gathered halo, per depth layer under the
    2.5D layout, optionally issued *before* wavefront 0 so it overlaps
    communication-free compute (``spec.overlap``).  On a CPU host, force
    a multi-device platform with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.  A trivial
    mesh (one device, or ``mesh=None``) falls back to single-device
    dispatch — including for ``backend="sharded"``.

    **Differentiable.**  When a dense operand is a JAX tracer (i.e. under
    ``jax.grad`` / ``jax.vjp`` / ``jax.jit`` of a differentiated
    function), the call routes through a ``jax.custom_vjp`` whose
    backward runs the transposed sparse products on this same fused
    dispatch — the Pallas/XLA/sharded executors serve the backward too,
    off schedule entries cached with ``transpose=True`` (inspected once
    per (content, shape), like the forward).  Eager calls with concrete
    operands — the serving hot path — skip the vjp machinery entirely.
    """
    spec = _coerce_spec(spec, legacy, "tile_fused_matmul")
    if backend not in BACKENDS:
        raise ValueError(f"backend={backend!r}; expected one of {BACKENDS}")
    c = jnp.asarray(c)
    knobs = dict(backend=backend, spec=spec)
    if isinstance(b_or_a1, CSR):
        if isinstance(c, jax.core.Tracer):
            return _spmm_spmm_diff(a, b_or_a1, knobs)(c)
        return _dispatch(a, b_or_a1, c, **knobs)
    b = jnp.asarray(b_or_a1)
    if isinstance(b, jax.core.Tracer) or isinstance(c, jax.core.Tracer):
        return _gemm_spmm_diff(a, knobs)(b, c)
    return _dispatch(a, b, c, **knobs)
