"""``FusionSpec`` — the one consolidated knob object of the tile-fusion API.

The dispatch seam (``api.get_schedule`` / ``api.tile_fused_matmul``) grew
twelve keyword knobs, duplicated across four cache-key derivations
(main key, autotune key, bucket publish, custom_vjp backward).  This
dataclass is the single source of truth for all of them: callers build one
frozen ``FusionSpec`` and pass ``spec=``; the spec's *resolved* form
(width cap concretized, mesh reduced to its hashable key, inert knobs
canonicalized on trivial meshes) **is** the schedule-cache key tail, so a
knob can never be part of dispatch without being part of the key.

The legacy keyword surface still works as a deprecation shim:
``get_schedule(a, ..., p=2, ct_size=32)`` builds a ``FusionSpec`` from the
kwargs and emits one structured ``DeprecationWarning`` per process (not
one per call — serving hot loops would drown in them).  New capability
lands as a spec field (``overlap``, ``n_repl``), not signature growth.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings

#: Legacy keyword names the shim maps onto spec fields (the historical
#: twelve plus the knobs added since).  Anything else is a typo and raises.
LEGACY_KNOBS = ("p", "cache_size", "ct_size", "uniform_split", "autotune",
                "width_cap", "mesh", "shard_combine", "shard_layout",
                "bucket", "transpose", "dtype_bytes", "overlap", "n_repl")


@dataclasses.dataclass(frozen=True)
class FusionSpec:
    """Every dispatch/inspection knob of the tile-fusion seam.

    Algorithm-1 knobs: ``p``, ``cache_size``, ``ct_size``,
    ``uniform_split``; sweep: ``autotune``; packing: ``width_cap``
    ("auto" | int | None); distribution: ``mesh``, ``shard_combine``,
    ``shard_layout`` ("auto" | "1d" | "1.5d" | "2.5d"), ``overlap``
    ("auto" | bool — async halo gather under wf0 compute), ``n_repl``
    (required total operand-replication factor across the mesh's
    replica × depth axes, None = let the layout pricing decide); serving:
    ``bucket``; training: ``transpose``; pricing: ``dtype_bytes`` (None =
    infer from the call's dense operands; ``get_schedule`` without
    operands defaults it to 4); schedule transform: ``reorder`` (None |
    "auto" | "rcm" | "similarity" — permute the pattern before
    inspection, "auto" applies the best candidate ordering only when the
    Eq-3 traffic model says it beats the identity by the dispatch floor;
    the permutation is baked into the cached entry, callers never
    apply/undo it themselves).

    Frozen and hashable on its own, but the *cache key* uses the resolved
    form ``api``'s key helper derives (a live ``Mesh`` object is not a
    cache key; "auto" width caps resolve per matrix).
    """

    p: int = 8
    cache_size: float = 600_000.0
    ct_size: int = 2048
    uniform_split: bool = True
    autotune: bool = False
    width_cap: int | str | None = "auto"
    mesh: object = None
    shard_combine: str = "auto"
    shard_layout: str = "auto"
    overlap: bool | str = "auto"
    n_repl: int | None = None
    bucket: tuple | None = None
    transpose: bool = False
    dtype_bytes: int | None = None
    reorder: str | None = None

    def __post_init__(self):
        if self.reorder not in (None, "auto", "rcm", "similarity"):
            raise ValueError(
                f"reorder={self.reorder!r}; expected None, 'auto', 'rcm' "
                f"or 'similarity'")
        if not isinstance(self.overlap, bool) and self.overlap != "auto":
            raise ValueError(
                f"overlap={self.overlap!r}; expected a bool or 'auto'")
        if self.n_repl is not None and int(self.n_repl) < 1:
            raise ValueError(f"n_repl={self.n_repl!r}; expected >= 1 or "
                             f"None")
        if self.bucket is not None:
            object.__setattr__(self, "bucket", tuple(self.bucket))


_warned = False
_warn_lock = threading.Lock()


def spec_from_legacy_kwargs(kwargs: dict, *, caller: str) -> FusionSpec:
    """Deprecation shim: build a ``FusionSpec`` from the historical keyword
    surface, warning once per process (structured, category
    ``DeprecationWarning``) with the caller and the knobs that triggered
    it.  Unknown keywords raise ``TypeError`` exactly like a real
    signature would."""
    global _warned
    unknown = sorted(set(kwargs) - set(LEGACY_KNOBS))
    if unknown:
        raise TypeError(f"{caller}() got unexpected keyword argument(s) "
                        f"{unknown}; knobs live on FusionSpec (spec=)")
    with _warn_lock:
        if not _warned:
            _warned = True
            warnings.warn(
                f"{caller}(**{sorted(kwargs)}): passing tile-fusion knobs "
                f"as keywords is deprecated; build a FusionSpec and pass "
                f"spec= (this warning is emitted once per process)",
                DeprecationWarning, stacklevel=3)
    return FusionSpec(**kwargs)


def reset_legacy_warning() -> None:
    """Re-arm the once-per-process deprecation warning (test hook, called
    by ``api.clear_schedule_cache`` so warning tests stay order-independent)."""
    global _warned
    with _warn_lock:
        _warned = False
