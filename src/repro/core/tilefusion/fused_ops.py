"""JAX executors for the fused schedule + the paper's baselines.

``fused_gemm_spmm`` / ``fused_spmm_spmm`` are the jit-compilable fused codes
(Listing 1 / Listing 3 of the paper, vmapped over tiles instead of OpenMP).
``unfused_*`` are the two-call baselines.  ``overlapped_*`` (CA-style
replication) and ``atomic_*`` (sparse-tiling-style multi-wavefront) are the
prior-work baselines of Figure 6/12, adapted as in paper §4.1.3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..sparse.formats import (CSR, HybridELL, TileELL, csr_content_digest,
                              ell_slot_coords)
from .schedule import DeviceSchedule


def _ell_rows(cols, vals, table):
    """rows[j] = Σ_w vals[j, w] · table[cols[j, w]] — scanned over w so the
    gather never materializes the (…, w, c_col) tensor (VMEM/cache friendly,
    mirrors the kernel's one-hot accumulation loop)."""
    w = cols.shape[-1]

    def body(acc, wv):
        cw, vw = wv                                     # (..., ) per slot
        return acc + vw[..., None] * table[cw], None

    init = jnp.zeros(cols.shape[:-1] + (table.shape[-1],), table.dtype)
    acc, _ = jax.lax.scan(body, init,
                          (jnp.moveaxis(cols, -1, 0), jnp.moveaxis(vals, -1, 0)))
    return acc


def _spill_add(d, spill_rows, spill_cols, spill_vals, table):
    """Scatter-add COO spill lanes: d[r] += v * table[c] for each lane.

    The hybrid-ELL tail pass: called after the body's ``.set`` scatter so a
    capped row's total is body + tail.  Zero lanes are a no-op (traced
    statically — callers may skip the call entirely when size is 0)."""
    return d.at[spill_rows].add(
        spill_vals.astype(table.dtype)[:, None] * table[spill_cols])


# --------------------------------------------------------------------------
# Fused executors (tile fusion)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("t_pad", "n_i", "n_j"))
def _fused_gemm_spmm_impl(b_pad, c, i_starts, j_rows0, cols0, vals0,
                          j_rows1, cols1, vals1, srows1, scols1, svals1,
                          *, t_pad, n_i, n_j):
    c_col = c.shape[1]

    # ---- wavefront 0: one vmapped step per fused tile ----
    def tile_fn(i_start, j_rows, cols, vals):
        b_t = jax.lax.dynamic_slice(b_pad, (i_start, 0), (t_pad, b_pad.shape[1]))
        d1_t = b_t @ c                                   # GeMM rows of the tile
        rows = _ell_rows(cols, vals, d1_t)               # fused SpMM rows
        return d1_t, rows

    d1_tiles, rows0 = jax.vmap(tile_fn)(i_starts, j_rows0, cols0, vals0)

    # stitch D1 (disjoint contiguous ranges; padded rows dropped)
    row_idx = (i_starts[:, None] + jnp.arange(t_pad)[None, :]).reshape(-1)
    row_idx = jnp.where(row_idx < n_i, row_idx, n_i)     # pad rows -> drop
    d1 = jnp.zeros((n_i, c_col), c.dtype).at[row_idx].set(
        d1_tiles.reshape(-1, c_col), mode="drop")
    d = jnp.zeros((n_j, c_col), c.dtype).at[j_rows0.reshape(-1)].set(
        rows0.reshape(-1, c_col), mode="drop")

    # ---- barrier; wavefront 1: global gather over D1 (body, then spill) ----
    if j_rows1.shape[0]:
        rows1 = _ell_rows(cols1, vals1, d1)              # (T1, j1_max, c_col)
        d = d.at[j_rows1.reshape(-1)].set(
            rows1.reshape(-1, c_col), mode="drop")
    if srows1.shape[0]:
        d = _spill_add(d, srows1, scols1, svals1, d1)
    return d


@functools.partial(jax.jit, static_argnames=("t", "n_i", "n_j"))
def _fused_gemm_spmm_uniform(b_pad, c, j_rows0, cols0, vals0,
                             j_rows1, cols1, vals1, srows1, scols1, svals1,
                             *, t, n_i, n_j):
    """Uniform-tile fast path: one batched matmul, no dynamic slices, no
    padding waste — the executor twin of the Pallas kernel's grid."""
    c_col = c.shape[1]
    n_t = b_pad.shape[0] // t
    d1_tiles = jnp.einsum("tkb,bc->tkc", b_pad.reshape(n_t, t, -1), c)
    rows0 = jax.vmap(_ell_rows)(cols0, vals0, d1_tiles)
    d1 = d1_tiles.reshape(n_t * t, c_col)
    d = jnp.zeros((n_j, c_col), c.dtype).at[j_rows0.reshape(-1)].set(
        rows0.reshape(-1, c_col), mode="drop")
    if j_rows1.shape[0]:
        rows1 = _ell_rows(cols1, vals1, d1[:n_i])
        d = d.at[j_rows1.reshape(-1)].set(rows1.reshape(-1, c_col),
                                          mode="drop")
    if srows1.shape[0]:
        d = _spill_add(d, srows1, scols1, svals1, d1[:n_i])
    return d


def _is_uniform(dsched: DeviceSchedule) -> bool:
    """True when wavefront-0 tiles form one uniform grid of stride t_pad
    (the layout the batched-matmul fast path and the Pallas kernel need).
    An empty schedule is trivially uniform."""
    t = dsched.t_pad
    st = np.asarray(dsched.i_starts)
    ln = np.asarray(dsched.i_lens)
    if st.size == 0:
        return True
    return bool((st == np.arange(st.shape[0]) * t).all()
                and (ln[:-1] == t).all())


def _wf1_spill_args(dsched: DeviceSchedule, dtype):
    return (jnp.asarray(dsched.spill_rows1), jnp.asarray(dsched.spill_cols1),
            jnp.asarray(dsched.spill_vals1, dtype))


def fused_gemm_spmm(dsched: DeviceSchedule, b: jax.Array, c: jax.Array) -> jax.Array:
    if _is_uniform(dsched):
        t = dsched.t_pad
        n_t = dsched.n_tiles0
        b_pad = jnp.pad(b, ((0, n_t * t - b.shape[0]), (0, 0)))
        return _fused_gemm_spmm_uniform(
            b_pad, c, jnp.asarray(dsched.j_rows0),
            jnp.asarray(dsched.ell_cols0),
            jnp.asarray(dsched.ell_vals0, c.dtype),
            jnp.asarray(dsched.j_rows1), jnp.asarray(dsched.ell_cols1),
            jnp.asarray(dsched.ell_vals1, c.dtype),
            *_wf1_spill_args(dsched, c.dtype),
            t=t, n_i=dsched.n_i, n_j=dsched.n_j)
    b_pad = jnp.pad(b, ((0, dsched.t_pad), (0, 0)))
    return _fused_gemm_spmm_impl(
        b_pad, c,
        jnp.asarray(dsched.i_starts), jnp.asarray(dsched.j_rows0),
        jnp.asarray(dsched.ell_cols0), jnp.asarray(dsched.ell_vals0, c.dtype),
        jnp.asarray(dsched.j_rows1), jnp.asarray(dsched.ell_cols1),
        jnp.asarray(dsched.ell_vals1, c.dtype),
        *_wf1_spill_args(dsched, c.dtype),
        t_pad=dsched.t_pad, n_i=dsched.n_i, n_j=dsched.n_j)


@functools.partial(jax.jit, static_argnames=("t_pad", "n_i", "n_j"))
def _fused_spmm_spmm_impl(c, i_starts, op1_cols, op1_vals, d1_spill,
                          j_rows0, cols0, vals0, j_rows1, cols1, vals1,
                          srows1, scols1, svals1, *, t_pad, n_i, n_j):
    c_col = c.shape[1]

    def tile_fn(i_start, o_cols, o_vals, d1_sp, j_rows, cols, vals):
        # op1 SpMM rows of the tile: hybrid ELL body over global C, plus the
        # tile's precomputed spill delta (hub-row tails past the width cap)
        d1_t = _ell_rows(o_cols, o_vals, c) + d1_sp
        rows = _ell_rows(cols, vals, d1_t)               # in-tile gather
        return d1_t, rows

    d1_tiles, rows0 = jax.vmap(tile_fn)(
        i_starts, op1_cols, op1_vals, d1_spill, j_rows0, cols0, vals0)

    row_idx = (i_starts[:, None] + jnp.arange(t_pad)[None, :]).reshape(-1)
    row_idx = jnp.where(row_idx < n_i, row_idx, n_i)
    d1 = jnp.zeros((n_i, c_col), c.dtype).at[row_idx].set(
        d1_tiles.reshape(-1, c_col), mode="drop")
    d = jnp.zeros((n_j, c_col), c.dtype).at[j_rows0.reshape(-1)].set(
        rows0.reshape(-1, c_col), mode="drop")

    if j_rows1.shape[0]:
        rows1 = _ell_rows(cols1, vals1, d1)
        d = d.at[j_rows1.reshape(-1)].set(rows1.reshape(-1, c_col), mode="drop")
    if srows1.shape[0]:
        d = _spill_add(d, srows1, scols1, svals1, d1)
    return d


def _op1_ell(a1: CSR, dsched: DeviceSchedule, width_cap: int | None = None):
    """Per-tile hybrid ELL of the op-1 rows (global columns into C).

    Routes through the shared ``HybridELL`` packer (one packer for every
    ELL in the system): the tiles' contiguous row ranges are concatenated
    into one packed row set, the body comes back reshaped to
    ``(T0, t_pad, w)``, and entries past ``width_cap`` come back as flat
    spill lanes addressed by *tile-padded* D1 position
    (``tile * t_pad + in_tile_slot``) so executors can scatter-add them
    onto the flattened D1 tiles before the in-tile gather runs.

    Memoized on the (cached) DeviceSchedule per op-1 content: the O(nnz)
    host repack runs once per (schedule, a1, cap), not once per executor
    call — the same amortization contract as the schedule cache itself."""
    memo_key = (csr_content_digest(a1),
                None if width_cap is None else int(width_cap))
    memo = getattr(dsched, "_op1_pack_memo", None)
    if memo is not None and memo[0] == memo_key:
        return memo[1]
    packed = _op1_ell_build(a1, dsched, width_cap)
    object.__setattr__(dsched, "_op1_pack_memo", (memo_key, packed))
    return packed


def _op1_ell_build(a1: CSR, dsched: DeviceSchedule, width_cap: int | None):
    t_pad = dsched.t_pad
    n_t = dsched.n_tiles0
    i_lens = np.asarray(dsched.i_lens, dtype=np.int64)
    w_cap = int(width_cap) if width_cap is not None else None
    if not int(i_lens.sum()):
        w = 1 if w_cap is None else max(min(w_cap, 1), 1)
        return (np.zeros((n_t, t_pad, w), np.int32),
                np.zeros((n_t, t_pad, w), np.float32),
                np.zeros(0, np.int64), np.zeros(0, np.int32),
                np.zeros(0, np.float32))
    tile_of, k_of = ell_slot_coords(i_lens)         # ranges concatenated
    rows = np.asarray(dsched.i_starts, np.int64)[tile_of] + k_of
    hell = HybridELL.from_csr_rows(
        a1, rows, cap=w_cap if w_cap is not None else a1.n_cols)
    w = hell.width
    cols = np.zeros((n_t, t_pad, w), np.int32)
    vals = np.zeros((n_t, t_pad, w), np.float32)
    cols[tile_of, k_of] = hell.cols
    vals[tile_of, k_of] = hell.vals.astype(np.float32)
    sr = hell.spill_rows.astype(np.int64)           # packed-row index
    spill_flat = tile_of[sr] * np.int64(t_pad) + k_of[sr]
    return (cols, vals, spill_flat, hell.spill_cols,
            hell.spill_vals.astype(np.float32))


def fused_spmm_spmm(dsched: DeviceSchedule, a1: CSR, c: jax.Array) -> jax.Array:
    cols, vals, spill_flat, spill_cols, spill_vals = _op1_ell(
        a1, dsched, width_cap=dsched.width_cap)
    n_t, t_pad = dsched.n_tiles0, dsched.t_pad
    c_col = c.shape[1]
    # spill delta on the flattened padded D1 tiles, zero when nothing spills
    d1_spill = jnp.zeros((n_t * t_pad, c_col), c.dtype)
    if spill_flat.size:
        d1_spill = _spill_add(d1_spill, jnp.asarray(spill_flat),
                              jnp.asarray(spill_cols),
                              jnp.asarray(spill_vals, c.dtype), c)
    return _fused_spmm_spmm_impl(
        c, jnp.asarray(dsched.i_starts), jnp.asarray(cols),
        jnp.asarray(vals, c.dtype), d1_spill.reshape(n_t, t_pad, c_col),
        jnp.asarray(dsched.j_rows0), jnp.asarray(dsched.ell_cols0),
        jnp.asarray(dsched.ell_vals0, c.dtype),
        jnp.asarray(dsched.j_rows1), jnp.asarray(dsched.ell_cols1),
        jnp.asarray(dsched.ell_vals1, c.dtype),
        *_wf1_spill_args(dsched, c.dtype),
        t_pad=dsched.t_pad, n_i=dsched.n_i, n_j=dsched.n_j)


# --------------------------------------------------------------------------
# Unfused baselines (two separate routines, D1 round-trips memory)
# --------------------------------------------------------------------------
def csr_to_ell(a: CSR, width_cap: int | None = None):
    """Full-matrix hybrid ELL (the unfused executor's format).

    Returns the 5-tuple ``(cols, vals, spill_rows, spill_cols, spill_vals)``
    of device arrays; with ``width_cap=None`` the body is pad-to-max and the
    spill lanes are empty (the pre-hybrid layout)."""
    hell = HybridELL.from_csr_rows(
        a, np.arange(a.n_rows),
        cap=width_cap if width_cap is not None else max(a.n_cols, 1))
    return (jnp.asarray(hell.cols), jnp.asarray(hell.vals, jnp.float32),
            jnp.asarray(hell.spill_rows), jnp.asarray(hell.spill_cols),
            jnp.asarray(hell.spill_vals, jnp.float32))


@jax.jit
def spmm_ell(cols: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    """Row-ELL SpMM: D[i] = sum_w vals[i,w] * X[cols[i,w]]."""
    return _ell_rows(cols, vals.astype(x.dtype), x)


@jax.jit
def spmm_hybrid(cols, vals, srows, scols, svals, x):
    """Hybrid-ELL SpMM: capped body pass + spill-lane scatter-add."""
    d = _ell_rows(cols, vals.astype(x.dtype), x)
    if srows.shape[0]:
        d = _spill_add(d, srows, scols, svals, x)
    return d


@jax.jit
def unfused_gemm_spmm(cols, vals, srows, scols, svals, b, c):
    d1 = b @ c
    return spmm_hybrid(cols, vals, srows, scols, svals, d1)


@jax.jit
def unfused_spmm_spmm(cols_a, vals_a, srows_a, scols_a, svals_a,
                      cols_a1, vals_a1, srows_a1, scols_a1, svals_a1, c):
    d1 = spmm_hybrid(cols_a1, vals_a1, srows_a1, scols_a1, svals_a1, c)
    return spmm_hybrid(cols_a, vals_a, srows_a, scols_a, svals_a, d1)


# --------------------------------------------------------------------------
# Prior-work baselines (paper §4.1.3 adaptations)
# --------------------------------------------------------------------------
def overlapped_tiles(a: CSR, p: int):
    """CA-style overlapped tiling: equal partitions of J; every partition
    *replicates* all D1 rows its J rows depend on (no synchronization,
    redundant compute).  Returns per-partition (dep_rows, j_rows)."""
    parts = np.array_split(np.arange(a.n_rows, dtype=np.int32), p)
    out = []
    for jr in parts:
        if jr.size == 0:
            continue
        deps = np.unique(np.concatenate(
            [a.indices[a.indptr[j]:a.indptr[j + 1]] for j in jr]
        )) if jr.size else np.zeros(0, np.int32)
        out.append((deps.astype(np.int32), jr))
    return out


def overlapped_gemm_spmm(a: CSR, parts, b: jax.Array, c: jax.Array) -> jax.Array:
    """Executes the overlapped schedule; counts replicated GeMV work."""
    n_j, c_col = a.n_rows, c.shape[1]
    d = jnp.zeros((n_j, c_col), c.dtype)
    for deps, jr in parts:
        ell = TileELL.from_csr_rows(a, jr)
        # remap global dep columns -> local replicated rows
        remap = np.zeros(a.n_cols, np.int32)
        remap[deps] = np.arange(deps.shape[0], dtype=np.int32)
        loc = remap[ell.cols]
        d1_rep = b[jnp.asarray(deps)] @ c              # replicated compute
        rows = jnp.einsum("jw,jwc->jc",
                          jnp.asarray(ell.vals, c.dtype), d1_rep[jnp.asarray(loc)])
        d = d.at[jnp.asarray(jr)].set(rows)
    return d


def overlapped_redundancy(a: CSR, p: int) -> float:
    """Replicated op-1 iterations / |I| (paper's G2_circuit/inline_1 metric)."""
    parts = overlapped_tiles(a, p)
    total = sum(int(d.shape[0]) for d, _ in parts)
    return total / max(a.n_cols, 1)


def atomic_tiles(a: CSR, p: int, n_waves: int = 4):
    """Sparse-tiling-style schedule: J rows partitioned into p*n_waves tiles;
    each wave is a synchronization barrier (multi-wavefront, vs tile fusion's
    single barrier).  Models the synchronization overhead, not CPU atomics."""
    parts = np.array_split(np.arange(a.n_rows, dtype=np.int32), p * n_waves)
    waves = [parts[w::n_waves] for w in range(n_waves)]
    return waves


def atomic_gemm_spmm(a: CSR, waves, b: jax.Array, c: jax.Array) -> jax.Array:
    n_j, c_col = a.n_rows, c.shape[1]
    d1 = b @ c
    d1.block_until_ready()                     # producer barrier
    d = jnp.zeros((n_j, c_col), c.dtype)
    for wave in waves:
        for jr in wave:
            if jr.size == 0:
                continue
            ell = TileELL.from_csr_rows(a, jr)
            rows = jnp.einsum("jw,jwc->jc", jnp.asarray(ell.vals, c.dtype),
                              d1[jnp.asarray(ell.cols)])
            d = d.at[jnp.asarray(jr)].set(rows)
        d.block_until_ready()                  # per-wave barrier
    return d
