"""JAX executors for the fused schedule + the paper's baselines.

``fused_gemm_spmm`` / ``fused_spmm_spmm`` are the jit-compilable fused codes
(Listing 1 / Listing 3 of the paper, vmapped over tiles instead of OpenMP).
``unfused_*`` are the two-call baselines.  ``overlapped_*`` (CA-style
replication) and ``atomic_*`` (sparse-tiling-style multi-wavefront) are the
prior-work baselines of Figure 6/12, adapted as in paper §4.1.3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..sparse.formats import CSR, TileELL, csr_gather_rows, ell_slot_coords
from .schedule import DeviceSchedule


def _ell_rows(cols, vals, table):
    """rows[j] = Σ_w vals[j, w] · table[cols[j, w]] — scanned over w so the
    gather never materializes the (…, w, c_col) tensor (VMEM/cache friendly,
    mirrors the kernel's one-hot accumulation loop)."""
    w = cols.shape[-1]

    def body(acc, wv):
        cw, vw = wv                                     # (..., ) per slot
        return acc + vw[..., None] * table[cw], None

    init = jnp.zeros(cols.shape[:-1] + (table.shape[-1],), table.dtype)
    acc, _ = jax.lax.scan(body, init,
                          (jnp.moveaxis(cols, -1, 0), jnp.moveaxis(vals, -1, 0)))
    return acc


# --------------------------------------------------------------------------
# Fused executors (tile fusion)
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("t_pad", "n_i", "n_j"))
def _fused_gemm_spmm_impl(b_pad, c, i_starts, j_rows0, cols0, vals0,
                          j_rows1, cols1, vals1, *, t_pad, n_i, n_j):
    c_col = c.shape[1]

    # ---- wavefront 0: one vmapped step per fused tile ----
    def tile_fn(i_start, j_rows, cols, vals):
        b_t = jax.lax.dynamic_slice(b_pad, (i_start, 0), (t_pad, b_pad.shape[1]))
        d1_t = b_t @ c                                   # GeMM rows of the tile
        rows = _ell_rows(cols, vals, d1_t)               # fused SpMM rows
        return d1_t, rows

    d1_tiles, rows0 = jax.vmap(tile_fn)(i_starts, j_rows0, cols0, vals0)

    # stitch D1 (disjoint contiguous ranges; padded rows dropped)
    row_idx = (i_starts[:, None] + jnp.arange(t_pad)[None, :]).reshape(-1)
    row_idx = jnp.where(row_idx < n_i, row_idx, n_i)     # pad rows -> drop
    d1 = jnp.zeros((n_i, c_col), c.dtype).at[row_idx].set(
        d1_tiles.reshape(-1, c_col), mode="drop")
    d = jnp.zeros((n_j, c_col), c.dtype).at[j_rows0.reshape(-1)].set(
        rows0.reshape(-1, c_col), mode="drop")

    # ---- barrier; wavefront 1: global gather over D1 ----
    if j_rows1.shape[0]:
        rows1 = _ell_rows(cols1, vals1, d1)              # (T1, j1_max, c_col)
        d = d.at[j_rows1.reshape(-1)].set(
            rows1.reshape(-1, c_col), mode="drop")
    return d


@functools.partial(jax.jit, static_argnames=("t", "n_i", "n_j"))
def _fused_gemm_spmm_uniform(b_pad, c, j_rows0, cols0, vals0,
                             j_rows1, cols1, vals1, *, t, n_i, n_j):
    """Uniform-tile fast path: one batched matmul, no dynamic slices, no
    padding waste — the executor twin of the Pallas kernel's grid."""
    c_col = c.shape[1]
    n_t = b_pad.shape[0] // t
    d1_tiles = jnp.einsum("tkb,bc->tkc", b_pad.reshape(n_t, t, -1), c)
    rows0 = jax.vmap(_ell_rows)(cols0, vals0, d1_tiles)
    d1 = d1_tiles.reshape(n_t * t, c_col)
    d = jnp.zeros((n_j, c_col), c.dtype).at[j_rows0.reshape(-1)].set(
        rows0.reshape(-1, c_col), mode="drop")
    if j_rows1.shape[0]:
        rows1 = _ell_rows(cols1, vals1, d1[:n_i])
        d = d.at[j_rows1.reshape(-1)].set(rows1.reshape(-1, c_col),
                                          mode="drop")
    return d


def _is_uniform(dsched: DeviceSchedule) -> bool:
    """True when wavefront-0 tiles form one uniform grid of stride t_pad
    (the layout the batched-matmul fast path and the Pallas kernel need).
    An empty schedule is trivially uniform."""
    t = dsched.t_pad
    st = np.asarray(dsched.i_starts)
    ln = np.asarray(dsched.i_lens)
    if st.size == 0:
        return True
    return bool((st == np.arange(st.shape[0]) * t).all()
                and (ln[:-1] == t).all())


def fused_gemm_spmm(dsched: DeviceSchedule, b: jax.Array, c: jax.Array) -> jax.Array:
    if _is_uniform(dsched):
        t = dsched.t_pad
        n_t = dsched.n_tiles0
        b_pad = jnp.pad(b, ((0, n_t * t - b.shape[0]), (0, 0)))
        return _fused_gemm_spmm_uniform(
            b_pad, c, jnp.asarray(dsched.j_rows0),
            jnp.asarray(dsched.ell_cols0),
            jnp.asarray(dsched.ell_vals0, c.dtype),
            jnp.asarray(dsched.j_rows1), jnp.asarray(dsched.ell_cols1),
            jnp.asarray(dsched.ell_vals1, c.dtype),
            t=t, n_i=dsched.n_i, n_j=dsched.n_j)
    b_pad = jnp.pad(b, ((0, dsched.t_pad), (0, 0)))
    return _fused_gemm_spmm_impl(
        b_pad, c,
        jnp.asarray(dsched.i_starts), jnp.asarray(dsched.j_rows0),
        jnp.asarray(dsched.ell_cols0), jnp.asarray(dsched.ell_vals0, c.dtype),
        jnp.asarray(dsched.j_rows1), jnp.asarray(dsched.ell_cols1),
        jnp.asarray(dsched.ell_vals1, c.dtype),
        t_pad=dsched.t_pad, n_i=dsched.n_i, n_j=dsched.n_j)


@functools.partial(jax.jit, static_argnames=("t_pad", "n_i", "n_j"))
def _fused_spmm_spmm_impl(c, i_starts, op1_cols, op1_vals,
                          j_rows0, cols0, vals0, j_rows1, cols1, vals1,
                          *, t_pad, n_i, n_j):
    c_col = c.shape[1]

    def tile_fn(i_start, o_cols, o_vals, j_rows, cols, vals):
        # op1 SpMM rows of the tile (ELL over global C)
        d1_t = _ell_rows(o_cols, o_vals, c)
        rows = _ell_rows(cols, vals, d1_t)               # in-tile gather
        return d1_t, rows

    d1_tiles, rows0 = jax.vmap(tile_fn)(
        i_starts, op1_cols, op1_vals, j_rows0, cols0, vals0)

    row_idx = (i_starts[:, None] + jnp.arange(t_pad)[None, :]).reshape(-1)
    row_idx = jnp.where(row_idx < n_i, row_idx, n_i)
    d1 = jnp.zeros((n_i, c_col), c.dtype).at[row_idx].set(
        d1_tiles.reshape(-1, c_col), mode="drop")
    d = jnp.zeros((n_j, c_col), c.dtype).at[j_rows0.reshape(-1)].set(
        rows0.reshape(-1, c_col), mode="drop")

    if j_rows1.shape[0]:
        rows1 = _ell_rows(cols1, vals1, d1)
        d = d.at[j_rows1.reshape(-1)].set(rows1.reshape(-1, c_col), mode="drop")
    return d


def _op1_ell(a1: CSR, dsched: DeviceSchedule):
    """Per-tile padded ELL of the op-1 rows (global columns into C).

    Vectorized: the tiles' contiguous row ranges are expanded into one flat
    row vector with (tile, in-tile-slot) coordinates, then all nonzeros are
    scattered by index arithmetic — no per-tile / per-row Python loops."""
    t_pad = dsched.t_pad
    n_t = dsched.n_tiles0
    counts = np.diff(a1.indptr)
    w = int(counts.max()) if counts.size else 1
    w = max(w, 1)
    cols = np.zeros((n_t, t_pad, w), np.int32)
    vals = np.zeros((n_t, t_pad, w), np.float32)
    i_lens = np.asarray(dsched.i_lens, dtype=np.int64)
    if int(i_lens.sum()):
        tile_of, k_of = ell_slot_coords(i_lens)     # ranges concatenated
        rows = np.asarray(dsched.i_starts, np.int64)[tile_of] + k_of
        flat, lens = csr_gather_rows(a1, rows)
        if flat.size:
            row_rep, w_idx = ell_slot_coords(lens)
            cols[tile_of[row_rep], k_of[row_rep], w_idx] = a1.indices[flat]
            vals[tile_of[row_rep], k_of[row_rep], w_idx] = \
                a1.data[flat].astype(np.float32)
    return cols, vals


def fused_spmm_spmm(dsched: DeviceSchedule, a1: CSR, c: jax.Array) -> jax.Array:
    cols, vals = _op1_ell(a1, dsched)
    return _fused_spmm_spmm_impl(
        c, jnp.asarray(dsched.i_starts), jnp.asarray(cols),
        jnp.asarray(vals, c.dtype),
        jnp.asarray(dsched.j_rows0), jnp.asarray(dsched.ell_cols0),
        jnp.asarray(dsched.ell_vals0, c.dtype),
        jnp.asarray(dsched.j_rows1), jnp.asarray(dsched.ell_cols1),
        jnp.asarray(dsched.ell_vals1, c.dtype),
        t_pad=dsched.t_pad, n_i=dsched.n_i, n_j=dsched.n_j)


# --------------------------------------------------------------------------
# Unfused baselines (two separate routines, D1 round-trips memory)
# --------------------------------------------------------------------------
def csr_to_ell(a: CSR):
    ell = TileELL.from_csr_rows(a, np.arange(a.n_rows))
    return jnp.asarray(ell.cols), jnp.asarray(ell.vals, jnp.float32)


@jax.jit
def spmm_ell(cols: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    """Row-ELL SpMM: D[i] = sum_w vals[i,w] * X[cols[i,w]]."""
    return _ell_rows(cols, vals.astype(x.dtype), x)


@jax.jit
def unfused_gemm_spmm(cols, vals, b, c):
    d1 = b @ c
    return spmm_ell(cols, vals, d1)


@jax.jit
def unfused_spmm_spmm(cols_a, vals_a, cols_a1, vals_a1, c):
    d1 = spmm_ell(cols_a1, vals_a1, c)
    return spmm_ell(cols_a, vals_a, d1)


# --------------------------------------------------------------------------
# Prior-work baselines (paper §4.1.3 adaptations)
# --------------------------------------------------------------------------
def overlapped_tiles(a: CSR, p: int):
    """CA-style overlapped tiling: equal partitions of J; every partition
    *replicates* all D1 rows its J rows depend on (no synchronization,
    redundant compute).  Returns per-partition (dep_rows, j_rows)."""
    parts = np.array_split(np.arange(a.n_rows, dtype=np.int32), p)
    out = []
    for jr in parts:
        if jr.size == 0:
            continue
        deps = np.unique(np.concatenate(
            [a.indices[a.indptr[j]:a.indptr[j + 1]] for j in jr]
        )) if jr.size else np.zeros(0, np.int32)
        out.append((deps.astype(np.int32), jr))
    return out


def overlapped_gemm_spmm(a: CSR, parts, b: jax.Array, c: jax.Array) -> jax.Array:
    """Executes the overlapped schedule; counts replicated GeMV work."""
    n_j, c_col = a.n_rows, c.shape[1]
    d = jnp.zeros((n_j, c_col), c.dtype)
    for deps, jr in parts:
        ell = TileELL.from_csr_rows(a, jr)
        # remap global dep columns -> local replicated rows
        remap = np.zeros(a.n_cols, np.int32)
        remap[deps] = np.arange(deps.shape[0], dtype=np.int32)
        loc = remap[ell.cols]
        d1_rep = b[jnp.asarray(deps)] @ c              # replicated compute
        rows = jnp.einsum("jw,jwc->jc",
                          jnp.asarray(ell.vals, c.dtype), d1_rep[jnp.asarray(loc)])
        d = d.at[jnp.asarray(jr)].set(rows)
    return d


def overlapped_redundancy(a: CSR, p: int) -> float:
    """Replicated op-1 iterations / |I| (paper's G2_circuit/inline_1 metric)."""
    parts = overlapped_tiles(a, p)
    total = sum(int(d.shape[0]) for d, _ in parts)
    return total / max(a.n_cols, 1)


def atomic_tiles(a: CSR, p: int, n_waves: int = 4):
    """Sparse-tiling-style schedule: J rows partitioned into p*n_waves tiles;
    each wave is a synchronization barrier (multi-wavefront, vs tile fusion's
    single barrier).  Models the synchronization overhead, not CPU atomics."""
    parts = np.array_split(np.arange(a.n_rows, dtype=np.int32), p * n_waves)
    waves = [parts[w::n_waves] for w in range(n_waves)]
    return waves


def atomic_gemm_spmm(a: CSR, waves, b: jax.Array, c: jax.Array) -> jax.Array:
    n_j, c_col = a.n_rows, c.shape[1]
    d1 = b @ c
    d1.block_until_ready()                     # producer barrier
    d = jnp.zeros((n_j, c_col), c.dtype)
    for wave in waves:
        for jr in wave:
            if jr.size == 0:
                continue
            ell = TileELL.from_csr_rows(a, jr)
            rows = jnp.einsum("jw,jwc->jc", jnp.asarray(ell.vals, c.dtype),
                              d1[jnp.asarray(ell.cols)])
            d = d.at[jnp.asarray(jr)].set(rows)
        d.block_until_ready()                  # per-wave barrier
    return d
