#!/usr/bin/env python
"""Run every benchmark driver at one tiny problem size (bit-rot check).

Equivalent to ``python -m benchmarks.run --smoke``; exists so CI can call a
single script without remembering the flag.  Run from the repo root with
``PYTHONPATH=src``.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import run  # noqa: E402


def main() -> None:
    sys.argv = [sys.argv[0], "--smoke"] + sys.argv[1:]
    run.main()


if __name__ == "__main__":
    main()
