#!/usr/bin/env python
"""Run every benchmark driver at one tiny problem size (bit-rot check).

Equivalent to ``python -m benchmarks.run --smoke --json``; exists so CI can
call a single script without remembering the flags.  Run from the repo root
with ``PYTHONPATH=src``.

After the run, the two newest ``BENCH_*.json`` artifacts in the working
directory are diffed row by row (per-row ``us`` delta plus any numeric
derived keys that moved) for trend reporting — smoke timings are noisy,
but a derived metric (hit rate, fused ratio, max grad error) drifting
between runs is a real signal.

``--check`` additionally (a) forwards to ``benchmarks.run --check`` so the
absolute thresholds gate, and (b) fails if any GATED row — a row matched
by a ``benchmarks/thresholds.json`` entry — regressed by more than 20%
between the two newest artifacts: slower ``us``, or the gated derived key
moving >20% toward its bound (down for ``min`` gates, up for ``max``).
Ungated rows only ever produce trend chatter, never a failure.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import run  # noqa: E402


def diff_latest(directory: str = ".", out=sys.stdout) -> None:
    """Diff the two newest BENCH_*.json artifacts by row name."""
    paths = sorted(glob.glob(os.path.join(directory, "BENCH_*.json")),
                   key=os.path.getmtime)
    if len(paths) < 2:
        print("# trend: fewer than two BENCH_*.json artifacts, no diff",
              file=out)
        return
    old_p, new_p = paths[-2], paths[-1]
    with open(old_p) as f:
        old = {r["name"]: r for r in json.load(f)["rows"]}
    with open(new_p) as f:
        new = {r["name"]: r for r in json.load(f)["rows"]}
    print(f"# trend: {os.path.basename(old_p)} -> {os.path.basename(new_p)}",
          file=out)
    for name in sorted(set(old) | set(new)):
        if name not in old:
            print(f"#   {name}: NEW", file=out)
            continue
        if name not in new:
            print(f"#   {name}: DROPPED", file=out)
            continue
        o, n = old[name], new[name]
        parts = []
        if o["us"]:
            parts.append(f"us {o['us']:.1f}->{n['us']:.1f} "
                         f"({(n['us'] - o['us']) / o['us'] * 100:+.0f}%)")
        for key, ov in sorted(o["derived"].items()):
            nv = n["derived"].get(key)
            if (isinstance(ov, float) and isinstance(nv, float)
                    and nv != ov):
                parts.append(f"{key} {ov:g}->{nv:g}")
        if parts:
            print(f"#   {name}: {'; '.join(parts)}", file=out)


def _latest_two(directory: str = "."):
    paths = sorted(glob.glob(os.path.join(directory, "BENCH_*.json")),
                   key=os.path.getmtime)
    return paths[-2:] if len(paths) >= 2 else None


def check_regressions(directory: str = ".", tolerance: float = 0.20) -> list:
    """>``tolerance`` regressions on gated rows between the two newest
    BENCH_*.json artifacts; list of violation strings (empty = pass)."""
    pair = _latest_two(directory)
    if pair is None:
        return []
    old_p, new_p = pair
    with open(old_p) as f:
        old = {r["name"]: r for r in json.load(f)["rows"]}
    with open(new_p) as f:
        new = {r["name"]: r for r in json.load(f)["rows"]}
    with open(run.THRESHOLDS_PATH) as f:
        thresholds = json.load(f)
    bad = []
    for th in thresholds:
        for name in sorted(set(old) & set(new)):
            if not name.startswith(th["row"]):
                continue
            o, n = old[name], new[name]
            if o["us"] and n["us"] > o["us"] * (1 + tolerance):
                bad.append(f"{name}: us {o['us']:.1f} -> {n['us']:.1f} "
                           f"(>{tolerance:.0%} slower)")
            key = th["key"]
            if key == "us":
                continue
            ov, nv = o["derived"].get(key), n["derived"].get(key)
            if not (isinstance(ov, float) and isinstance(nv, float)) or not ov:
                continue
            if "min" in th and nv < ov * (1 - tolerance):
                bad.append(f"{name}: {key} {ov:g} -> {nv:g} "
                           f"(>{tolerance:.0%} drop on a min-gated key)")
            if "max" in th and nv > ov * (1 + tolerance):
                bad.append(f"{name}: {key} {ov:g} -> {nv:g} "
                           f"(>{tolerance:.0%} rise on a max-gated key)")
    return sorted(set(bad))


def main() -> None:
    check = "--check" in sys.argv[1:]
    extra = [a for a in sys.argv[1:] if a != "--check"]
    sys.argv = ([sys.argv[0], "--smoke", "--json"]
                + (["--check"] if check else []) + extra)
    run.main()
    diff_latest()
    if check:
        bad = check_regressions()
        for v in bad:
            print(f"TREND REGRESSION: {v}", file=sys.stderr)
        if bad:
            sys.exit(1)
        print("# trend ok (gated rows within 20% of previous artifact)")


if __name__ == "__main__":
    main()
