#!/usr/bin/env python
"""Run every benchmark driver at one tiny problem size (bit-rot check).

Equivalent to ``python -m benchmarks.run --smoke --json``; exists so CI can
call a single script without remembering the flags.  Run from the repo root
with ``PYTHONPATH=src``.

After the run, the two newest ``BENCH_*.json`` artifacts in the working
directory are diffed row by row (per-row ``us`` delta plus any numeric
derived keys that moved) for trend reporting — smoke timings are noisy,
but a derived metric (hit rate, fused ratio, max grad error) drifting
between runs is a real signal.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import run  # noqa: E402


def diff_latest(directory: str = ".", out=sys.stdout) -> None:
    """Diff the two newest BENCH_*.json artifacts by row name."""
    paths = sorted(glob.glob(os.path.join(directory, "BENCH_*.json")),
                   key=os.path.getmtime)
    if len(paths) < 2:
        print("# trend: fewer than two BENCH_*.json artifacts, no diff",
              file=out)
        return
    old_p, new_p = paths[-2], paths[-1]
    with open(old_p) as f:
        old = {r["name"]: r for r in json.load(f)["rows"]}
    with open(new_p) as f:
        new = {r["name"]: r for r in json.load(f)["rows"]}
    print(f"# trend: {os.path.basename(old_p)} -> {os.path.basename(new_p)}",
          file=out)
    for name in sorted(set(old) | set(new)):
        if name not in old:
            print(f"#   {name}: NEW", file=out)
            continue
        if name not in new:
            print(f"#   {name}: DROPPED", file=out)
            continue
        o, n = old[name], new[name]
        parts = []
        if o["us"]:
            parts.append(f"us {o['us']:.1f}->{n['us']:.1f} "
                         f"({(n['us'] - o['us']) / o['us'] * 100:+.0f}%)")
        for key, ov in sorted(o["derived"].items()):
            nv = n["derived"].get(key)
            if (isinstance(ov, float) and isinstance(nv, float)
                    and nv != ov):
                parts.append(f"{key} {ov:g}->{nv:g}")
        if parts:
            print(f"#   {name}: {'; '.join(parts)}", file=out)


def main() -> None:
    sys.argv = [sys.argv[0], "--smoke", "--json"] + sys.argv[1:]
    run.main()
    diff_latest()


if __name__ == "__main__":
    main()
