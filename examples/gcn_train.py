"""End-to-end GCN training — the paper's native application at full size.

  PYTHONPATH=src python examples/gcn_train.py [--nodes 4096] [--steps 100]

GCN layer = D = Â(XW) = GeMM-SpMM; every layer and every step runs through
``tile_fused_matmul`` (schedule inspected once per graph, then served from
the content-keyed cache).  The backward runs on the fused path too — the
api's custom_vjp dispatches the transposed products off cached transpose
schedules.  Reports fused vs unfused wall time, per-layer traffic models,
and the train-step (fwd+bwd) traffic from the transpose entries.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gcn import GCNConfig
from repro.core.sparse.random import powerlaw_graph
from repro.core.tilefusion import api
from repro.launch.steps import make_gcn_train_step
from repro.models.gcn import GCN


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4096)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=0.3)
    args = ap.parse_args()

    cfg = GCNConfig(n_nodes=args.nodes, in_dim=args.hidden,
                    hidden_dim=args.hidden, out_dim=32, n_layers=2)
    adj = powerlaw_graph(cfg.n_nodes, cfg.avg_degree, seed=0)
    t0 = time.time()
    model = GCN(cfg, adj, cache_size=300_000.0)
    print(f"schedule inspect: {time.time()-t0:.2f}s (cached for every "
          f"layer/step), fused_ratio={model.sched.fused_ratio:.2f}, "
          f"tiles={len(model.sched.wavefronts[0])}+"
          f"{len(model.sched.wavefronts[1])}")
    for i, tm in enumerate(model.layer_traffic_models()):
        print(f"layer {i} ({model.dims[i]}->{model.dims[i+1]}): traffic "
              f"saving (kernel path) {100*tm['traffic_saving']:.0f}%")
    for i, tm in enumerate(model.train_step_traffic_models()):
        print(f"layer {i} train step: fwd {tm['forward_bytes']/1e6:.1f} MB "
              f"+ bwd {tm['backward_bytes']/1e6:.1f} MB "
              f"(bwd fused saving {100*tm['backward_saving']:.0f}%)")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((cfg.n_nodes, cfg.in_dim)),
                    jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.out_dim, cfg.n_nodes))
    params = model.init_params(jax.random.PRNGKey(0))

    for fused in (True, False):
        p = params
        # the fused leg runs backend="auto": Eq-3 picks the executor per
        # entry, falling back to the plain hybrid SpMM when the modeled
        # saving can't cover the tile loop's fixed costs — "fused" here
        # means "through the dispatch", never slower than the baseline
        be = "auto" if fused else "unfused"
        picks = ",".join(sorted({api.select_backend(e)
                                 for e in model.entries})) if fused else be
        step_fn = make_gcn_train_step(model, lr=args.lr, fused=fused,
                                      backend=be)
        jax.block_until_ready(step_fn(p, x, y))  # compile
        misses0 = api.schedule_cache_stats()["misses"]
        t0 = time.time()
        for _ in range(args.steps):
            p, loss = step_fn(p, x, y)
        jax.block_until_ready(loss)   # async dispatch would under-report
        dt = time.time() - t0
        # the printed loss is evaluated at the *post-loop* params — the
        # in-loop value lags one update behind the weights it's reported for
        final_loss = float(model.loss(p, x, y, fused=fused))
        stats = api.schedule_cache_stats()
        print(f"{f'fused[{picks}]' if fused else 'unfused'}: "
              f"{args.steps} steps "
              f"in {dt:.2f}s ({dt/args.steps*1e3:.1f} ms/step), "
              f"final loss {final_loss:.4f}, "
              f"re-inspections during loop: "
              f"{stats['misses'] - misses0}, "
              f"transpose entries: {stats['transpose_entries']}")


if __name__ == "__main__":
    main()
