"""End-to-end GCN training — the paper's native application at full size.

  PYTHONPATH=src python examples/gcn_train.py [--nodes 4096] [--steps 100]

GCN layer = D = Â(XW) = GeMM-SpMM; every layer and every step runs through
``tile_fused_matmul`` (schedule inspected once per graph, then served from
the content-keyed cache).  Reports fused vs unfused wall time and the
schedule's traffic model.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gcn import GCNConfig
from repro.core.sparse.random import powerlaw_graph
from repro.models.gcn import GCN


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4096)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=0.3)
    args = ap.parse_args()

    cfg = GCNConfig(n_nodes=args.nodes, in_dim=args.hidden,
                    hidden_dim=args.hidden, out_dim=32, n_layers=2)
    adj = powerlaw_graph(cfg.n_nodes, cfg.avg_degree, seed=0)
    t0 = time.time()
    model = GCN(cfg, adj, cache_size=300_000.0)
    print(f"schedule inspect: {time.time()-t0:.2f}s (cached for every "
          f"layer/step), fused_ratio={model.sched.fused_ratio:.2f}, "
          f"tiles={len(model.sched.wavefronts[0])}+"
          f"{len(model.sched.wavefronts[1])}")
    tm = model.entry.traffic_model
    print(f"traffic saving (kernel path): {100*tm['traffic_saving']:.0f}%")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((cfg.n_nodes, cfg.in_dim)),
                    jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.out_dim, cfg.n_nodes))
    params = model.init_params(jax.random.PRNGKey(0))

    for fused in (True, False):
        p = params
        lg = jax.jit(jax.value_and_grad(
            lambda p_: model.loss(p_, x, y, fused=fused)))
        jax.block_until_ready(lg(p))  # compile
        t0 = time.time()
        for step in range(args.steps):
            loss, grads = lg(p)
            p = jax.tree.map(lambda a_, g: a_ - args.lr * g, p, grads)
        jax.block_until_ready(p)      # async dispatch would under-report
        dt = time.time() - t0
        print(f"{'fused' if fused else 'unfused'}: {args.steps} steps "
              f"in {dt:.2f}s ({dt/args.steps*1e3:.1f} ms/step), "
              f"final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
