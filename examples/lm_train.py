"""End-to-end LM training driver: a ~25M-param dense transformer for a few
hundred steps with checkpoint/restart (the framework's full training path).

  PYTHONPATH=src python examples/lm_train.py [--steps 300]

(A ~100M+ model is a one-line config change — d_model=768, n_layers=12 —
but a few hundred steps of that is not a reasonable single-CPU-core demo;
the dry-run cells cover the large-scale path.)
"""
import argparse

from repro.configs.base import ModelConfig
from repro.configs import _MODULES  # registry
from repro.launch import train as train_mod

SMALL_LM = ModelConfig(
    name="small-lm-25m", family="dense",
    n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
    d_ff=1024, vocab_size=8192, remat="none",
)


class _Mod:
    CONFIG = SMALL_LM
    REDUCED = SMALL_LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_train_ckpt")
    args = ap.parse_args()
    _MODULES["small-lm-25m"] = _Mod  # register the example config
    train_mod.main([
        "--arch", "small-lm-25m", "--steps", str(args.steps),
        "--batch", "8", "--seq", "256",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    main()
