"""Serving example: batched decode on the MoE arch (tile-fusion flagship),
then a sampled-subgraph stream through the dynamic-pattern serving tier.

  PYTHONPATH=src python examples/moe_serve.py
"""
from repro.launch import serve


def main():
    serve.main(["--arch", "granite-moe-3b-a800m", "--reduced",
                "--batch", "4", "--prompt-len", "16", "--gen", "24"])
    # dynamic-pattern tier: bucketed schedule reuse + incremental
    # inspection + batched dispatch over a drifting subgraph stream
    serve.main(["--subgraphs", "24", "--subgraph-nodes", "192",
                "--feat-dim", "16", "--out-dim", "8", "--max-batch", "4"])


if __name__ == "__main__":
    main()
