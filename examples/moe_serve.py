"""Serving example: batched decode on the MoE arch (tile-fusion flagship).

  PYTHONPATH=src python examples/moe_serve.py
"""
from repro.launch import serve


def main():
    serve.main(["--arch", "granite-moe-3b-a800m", "--reduced",
                "--batch", "4", "--prompt-len", "16", "--gen", "24"])


if __name__ == "__main__":
    main()
