"""Quickstart: the paper's technique in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

Inspects a tile-fusion schedule for a graph matrix through the unified
dispatch API, validates the fused GeMM-SpMM against the unfused oracle,
prints schedule quality metrics, shows the inspector cache amortizing, and
trains a 2-layer GCN (the paper's native workload) for a few steps.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import gcn as gcn_cfg
from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import api, fused_ref
from repro.models.gcn import GCN

# ---- 1. inspect a GeMM-SpMM schedule: D = A (B C) ----
# banded SPD = the paper's scientific-computing matrix group (group I);
# swap in powerlaw_graph(...) for the graph group (lower fused ratio)
n, bcol, ccol = 2048, 64, 64
a = banded_spd(n, bandwidth=8, seed=0)
spec = api.FusionSpec(p=8, cache_size=300_000.0, ct_size=512)
entry = api.get_schedule(a, b_col=bcol, c_col=ccol, spec=spec)
sched = entry.sched
print(f"matrix: {n}x{n}, nnz={a.nnz}")
print(f"schedule: {len(sched.wavefronts[0])} fused tiles + "
      f"{len(sched.wavefronts[1])} wavefront-1 tiles, t={sched.t}, "
      f"fused_ratio={sched.fused_ratio:.2f} (1 barrier, 0 atomics)")

tm = entry.traffic_model
print(f"traffic model: fused moves {tm['fused_bytes']/1e6:.1f}MB vs "
      f"unfused {tm['unfused_bytes']/1e6:.1f}MB "
      f"({100*tm['traffic_saving']:.0f}% saved, "
      f"{tm['d1_spill_rows']}/{n} D1 rows spill past the barrier)")

# ---- 2. correctness vs oracle, dispatch + inspector amortization ----
rng = np.random.default_rng(0)
b = rng.standard_normal((n, bcol))
c = rng.standard_normal((bcol, ccol))
d_ref = fused_ref.unfused_gemm_spmm(a, b, c)
d = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                          jnp.asarray(c, jnp.float32), spec=spec)
err = float(np.abs(np.asarray(d) - d_ref).max() / np.abs(d_ref).max())
print(f"fused (backend=auto -> {api.select_backend(entry)}) "
      f"vs oracle rel err: {err:.2e}")
print(f"inspector: {entry.inspector_s*1e3:.1f}ms once, then cached — "
      f"stats {api.schedule_cache_stats()}")

# ---- 3. GCN training on the fused path ----
cfg = gcn_cfg.REDUCED
model = GCN(cfg, powerlaw_graph(cfg.n_nodes, cfg.avg_degree, seed=1))
params = model.init_params(jax.random.PRNGKey(0))
x = jnp.asarray(rng.standard_normal((cfg.n_nodes, cfg.in_dim)), jnp.float32)
y = jnp.asarray(rng.integers(0, cfg.out_dim, cfg.n_nodes))
loss_grad = jax.jit(jax.value_and_grad(
    lambda p: model.loss(p, x, y, fused=True)))
t0 = time.time()
for step in range(10):
    loss, grads = loss_grad(params)
    params = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    if step % 3 == 0:
        print(f"gcn step {step}: loss {float(loss):.4f}")
print(f"10 GCN steps in {time.time()-t0:.1f}s — schedule inspected once, "
      f"served from cache every step (paper §4.2.3)")
