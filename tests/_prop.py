"""Property-test shim: real hypothesis when installed, seeded sweeps otherwise.

The tier-1 suite must collect and run on machines without ``hypothesis``.
When it is missing, ``@given(x=st.integers(...))`` degrades to a
deterministic ``pytest.mark.parametrize`` sweep: ``max_examples`` cases are
drawn up front from a fixed seed, so every environment runs the same cases
and failures reproduce by test id.  Only the strategy subset this repo uses
is implemented (integers, floats, sampled_from, booleans), keyword-argument
``@given`` only.
"""
from __future__ import annotations

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

try:
    import os as _os

    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True

    # Deterministic profile, loaded by default wherever hypothesis IS
    # installed: fixed derivation instead of random exploration, bounded
    # example counts, no wall-clock deadline flakes, no cross-run example
    # database — a property failure then reproduces by test id alone,
    # matching the no-hypothesis fallback's seeded parametrize sweeps.
    # Opt back into exploratory runs with REPRO_HYPOTHESIS_PROFILE=default
    # (or any other registered profile name).
    settings.register_profile(
        "ci", settings(derandomize=True, max_examples=16, deadline=None,
                       database=None))
    settings.load_profile(_os.environ.get("REPRO_HYPOTHESIS_PROFILE", "ci"))
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np
    import pytest

    _DEFAULT_EXAMPLES = 10
    _SEED = 0x7E57_5EED

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda r: int(r.integers(lo, hi + 1)))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda r: float(r.uniform(lo, hi)))

        @staticmethod
        def sampled_from(xs):
            xs = list(xs)
            return _Strategy(lambda r: xs[int(r.integers(len(xs)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.integers(2)))

    st = _Strategies()

    def _parametrize(fn, strats, n):
        names = list(strats)
        cases = []
        for i in range(n):
            rng = np.random.default_rng(_SEED + 7919 * i)
            drawn = tuple(strats[k].draw(rng) for k in names)
            # pytest does not unpack 1-tuples for a single argname
            cases.append(drawn if len(names) > 1 else drawn[0])
        return pytest.mark.parametrize(",".join(names), cases)(fn)

    def given(**strats):
        def deco(fn):
            out = _parametrize(fn, strats, _DEFAULT_EXAMPLES)
            out._given_strats = strats
            return out
        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        """Applied above @given in this repo; re-draws the sweep at the
        requested size (dropping the default-sized parametrization)."""
        def deco(fn):
            strats = getattr(fn, "_given_strats", None)
            if strats is None:
                return fn
            fn.pytestmark = [m for m in getattr(fn, "pytestmark", [])
                             if m.name != "parametrize"]
            return _parametrize(fn, strats, max_examples)
        return deco
