"""Sharded tile-fusion dispatch: partition, halo, cache keying, shim.

Host-side structure tests (the partitioner and ``ShardedSchedule`` builder
are pure numpy) run everywhere; execution parity over a *real* multi-device
mesh runs in-process when the platform has >1 device (the CI leg forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and is additionally
pinned by a subprocess test that forces an 8-device host platform
regardless of how the suite itself was launched.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.sparse.random import banded_spd, hub_powerlaw, powerlaw_graph
from repro.core.tilefusion import api, fused_ref, sharded
from repro.core.tilefusion.cost_model import shard_comm_model
from repro.core.tilefusion.scheduler import balanced_contiguous_partition
from repro.models.sharding import shard_map

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KNOBS = dict(p=2, cache_size=30_000.0, ct_size=32)


@pytest.fixture(autouse=True)
def fresh_cache():
    api.clear_schedule_cache()
    yield
    api.clear_schedule_cache()


def _mesh(n: int | None = None) -> Mesh:
    devs = jax.devices()
    n = len(devs) if n is None else min(n, len(devs))
    return Mesh(np.array(devs[:n]), ("shards",))


# --------------------------------------------------------------------------
# Partitioner (host-side, device-count independent)
# --------------------------------------------------------------------------
def test_balanced_partition_contiguous_and_balanced():
    costs = np.array([5.0, 1.0, 1.0, 1.0, 5.0, 1.0, 1.0, 1.0])
    bounds = balanced_contiguous_partition(costs, 4)
    assert bounds[0] == 0 and bounds[-1] == costs.size
    assert (np.diff(bounds) >= 0).all()
    sums = np.add.reduceat(costs, bounds[:-1][np.diff(bounds) > 0])
    # bottleneck can never beat the largest single tile, and the balanced
    # split must do no worse than one hot shard carrying everything
    assert sums.max() >= costs.max()
    assert sums.max() < costs.sum()


def test_balanced_partition_more_shards_than_tiles():
    bounds = balanced_contiguous_partition(np.array([3.0, 2.0]), 8)
    assert bounds[0] == 0 and bounds[-1] == 2
    assert (np.diff(bounds) >= 0).all()
    assert np.diff(bounds).sum() == 2       # every tile assigned once


def test_balanced_partition_empty():
    bounds = balanced_contiguous_partition(np.zeros(0), 4)
    assert bounds.shape == (5,) and (bounds == 0).all()


def test_mesh_partition_resolves_layouts():
    """The mesh-aware front end: one layout rule decides row shards vs
    column replicas vs depth layers, and the row bounds follow it."""
    from repro.core.tilefusion.scheduler import (balanced_mesh_partition,
                                                 resolve_mesh_layout)
    costs = np.ones(8)
    # 1d flattens every axis into row shards
    bounds, n_row, n_repl, n_depth = balanced_mesh_partition(
        costs, (4, 2), "1d")
    assert (n_row, n_repl, n_depth) == (8, 1, 1) and bounds.shape == (9,)
    # 1.5d partitions over the leading axis only
    bounds, n_row, n_repl, n_depth = balanced_mesh_partition(
        costs, (4, 2), "1.5d")
    assert (n_row, n_repl, n_depth) == (4, 2, 1) and bounds.shape == (5,)
    assert np.diff(bounds).sum() == 8
    # 2.5d peels the axes past the second into depth layers
    assert resolve_mesh_layout((2, 2, 2), "2.5d") == (2, 2, 2)
    assert resolve_mesh_layout((2, 2, 2, 2), "2.5d") == (2, 2, 4)
    # nothing to column-replicate: depth folds into the replica slot
    assert resolve_mesh_layout((4, 1, 2), "2.5d") == (4, 2, 1)
    # degenerate cases walk down the ladder; bad layouts fail loudly
    assert resolve_mesh_layout((8,), "1.5d") == (8, 1, 1)
    assert resolve_mesh_layout(8, "1d") == (8, 1, 1)
    assert resolve_mesh_layout((4, 1), "1.5d") == (4, 1, 1)
    assert resolve_mesh_layout((4, 2), "2.5d") == (4, 2, 1)
    assert resolve_mesh_layout((8,), "2.5d") == (8, 1, 1)
    with pytest.raises(ValueError):
        resolve_mesh_layout((4, 2), "3d")


def test_shard_comm_model_prices_halo_vs_replication():
    m = shard_comm_model(8, halo_rows=16, n_i=256, c_col=8, n_j=512)
    assert m["halo_bytes"] < m["replicate_bytes"]
    assert m["halo_fraction"] == 16 / 256
    # the psum output combine moves full-D partials — the dominant term
    # for small halos, and priced on n_j (D rows), not n_i
    assert m["combine_bytes"] == 512 * 8 * 4 * (7 / 8) * 8
    assert m["combine_bytes"] > m["halo_bytes"]
    # the row-remapped reduce-scatter moves each owned block once instead
    # of every row to every device: strictly cheaper on a multi-shard mesh
    assert m["combine_bytes_reduce_scatter"] < m["combine_bytes"]
    assert m["combine"] == "reduce_scatter"
    assert m["layout"] == "1d" and m["n_repl"] == 1
    # single shard: no remote bytes at all
    m1 = shard_comm_model(1, halo_rows=16, n_i=256, c_col=8)
    assert m1["halo_bytes"] == 0.0 and m1["replicate_bytes"] == 0.0
    assert m1["combine_bytes"] == 0.0
    assert m1["combine_bytes_reduce_scatter"] == 0.0


def test_shard_comm_model_combine_preference_monotone():
    """``shard_comm_model`` must prefer the reduce-scatter combine exactly
    when the psum's combine bytes dominate — and the preference gap must
    grow monotonically with the output size that drives those bytes
    (synthetic byte-count fixtures, no devices needed)."""
    gaps = []
    for n_j in (64, 256, 1024, 4096):
        m = shard_comm_model(8, halo_rows=4, n_i=4096, c_col=32, n_j=n_j,
                             combine_rows=n_j + 8)    # ≈ n_j, padded
        # combine dominates the halo by construction
        assert m["combine_bytes"] > m["halo_bytes"]
        assert m["combine"] == "reduce_scatter"
        gaps.append(m["combine_bytes"] - m["combine_bytes_reduce_scatter"])
    assert all(g2 > g1 for g1, g2 in zip(gaps, gaps[1:]))
    # degenerate ownership (one shard owns everything, maximal padding):
    # reduce-scatter buys nothing, psum keeps the simpler collective
    worst = shard_comm_model(8, halo_rows=4, n_i=64, c_col=32, n_j=64,
                             combine_rows=64 * 8)
    assert worst["combine"] == "psum"


def test_choose_mesh_layout_prefers_replication_when_halo_dominates():
    """``choose_mesh_layout`` must flip a 2-D mesh from pure-1D to the
    replicated 1.5D layout exactly when the halo bytes it saves outgrow
    the operand copies it costs — monotonically in the halo size."""
    from repro.core.tilefusion.cost_model import choose_mesh_layout

    def pick(halo_rows):
        return choose_mesh_layout((4, 2), halo_rows=halo_rows, n_i=4096,
                                  n_j=4096, c_col=64,
                                  operand_bytes=64 * 1024 * 1024)

    layouts = [pick(h)["layout"] for h in (0, 64, 4096 * 4, 4096 * 64)]
    assert layouts[0] == "1d"              # nothing to save: don't copy A/B
    assert layouts[-1] == "1.5d"           # halo dominates: replicate
    # monotone: once replication pays, more halo never flips it back
    flips = [a != b for a, b in zip(layouts, layouts[1:])]
    assert sum(flips) <= 1
    # 1-D meshes have no replication axis to choose
    assert choose_mesh_layout((8,), halo_rows=10**9, n_i=4096, n_j=4096,
                              c_col=64, operand_bytes=1.0)["layout"] == "1d"
    # candidates expose both prices for the benchmark's derived columns
    cands = pick(4096 * 64)["candidates"]
    assert cands["1.5d"]["comm_bytes"] < cands["1d"]["comm_bytes"]
    assert cands["1.5d"]["replication_cost_bytes"] > 0.0


# --------------------------------------------------------------------------
# ShardedSchedule structure (host-side)
# --------------------------------------------------------------------------
def test_sharded_schedule_structure():
    a = powerlaw_graph(256, 5, seed=3)
    entry = api.get_schedule(a, b_col=8, c_col=8, **KNOBS)
    shard = sharded.build_sharded_schedule(
        a, entry.sched, entry.dsched, 4, b_col=8, c_col=8,
        b_is_sparse=False, width_cap=entry.width_cap)
    assert shard is not None and shard.n_shards == 4
    ds = entry.dsched
    # every wf0 tile assigned to exactly one shard, in order
    assert shard.tile_bounds[0] == 0
    assert shard.tile_bounds[-1] == ds.n_tiles0
    counts = shard.shard_tile_counts()
    assert counts.sum() == ds.n_tiles0
    # halo = exactly the wf1 dependency set, owned by row-block ranges
    halo = shard.halo_rows
    np.testing.assert_array_equal(halo, ds.wf1_dep_rows())
    row_bounds = shard.tile_bounds * shard.t_pad
    pos_seen = np.sort(shard.send_pos[shard.send_pos < shard.halo_size])
    np.testing.assert_array_equal(pos_seen, np.arange(shard.halo_size))
    for s in range(4):
        sl = shard.send_local.reshape(4, -1)[s]
        sp = shard.send_pos[0, s]           # (Z, S, Hs); Z == 1 here
        real = sp < shard.halo_size
        # each contributed halo row is inside the shard's own row block
        glob = sl[real] + row_bounds[s]
        assert ((glob >= row_bounds[s]) & (glob < row_bounds[s + 1])).all()
        np.testing.assert_array_equal(glob, halo[sp[real]])


def test_sharded_schedule_requires_uniform_grid():
    a = powerlaw_graph(128, 4, seed=1)
    entry = api.get_schedule(a, b_col=8, c_col=8, uniform_split=False,
                             p=2, cache_size=2_000.0, ct_size=32)
    if not api.fused_ops._is_uniform(entry.dsched):
        assert sharded.build_sharded_schedule(
            a, entry.sched, entry.dsched, 4, b_col=8, c_col=8,
            b_is_sparse=False, width_cap=entry.width_cap) is None


# --------------------------------------------------------------------------
# Cache keying: mesh shape is part of the schedule key
# --------------------------------------------------------------------------
def test_mesh_shape_misses_schedule_cache():
    a = banded_spd(128, 4, seed=0)
    e_plain = api.get_schedule(a, b_col=8, c_col=8, **KNOBS)
    assert api.schedule_cache_stats()["misses"] == 1
    assert api.schedule_cache_stats()["mesh_entries"] == 0

    mesh1 = _mesh(1)
    # a trivial mesh keys exactly like no mesh: pure hit, not a new entry
    assert api.get_schedule(a, b_col=8, c_col=8, mesh=mesh1,
                            **KNOBS) is e_plain
    assert api.schedule_cache_stats()["misses"] == 1

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device for non-trivial mesh keys "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    mesh_n = _mesh()
    e_mesh = api.get_schedule(a, b_col=8, c_col=8, mesh=mesh_n, **KNOBS)
    assert e_mesh is not e_plain            # same content, new mesh: miss
    assert e_mesh.shard is not None
    stats = api.schedule_cache_stats()
    assert stats["misses"] == 2 and stats["mesh_entries"] == 1
    # same mesh shape under a different Mesh object: hit
    assert api.get_schedule(a, b_col=8, c_col=8, mesh=_mesh(),
                            **KNOBS) is e_mesh
    # a different mesh *shape* over the same devices: miss again
    devs = jax.devices()
    mesh_2d = Mesh(np.array(devs).reshape(2, -1), ("x", "y"))
    e_2d = api.get_schedule(a, b_col=8, c_col=8, mesh=mesh_2d, **KNOBS)
    assert e_2d is not e_mesh
    stats = api.schedule_cache_stats()
    assert stats["misses"] == 3 and stats["mesh_entries"] == 2


def test_trivial_mesh_falls_back_single_device():
    a = banded_spd(64, 4, seed=2)
    rng = np.random.default_rng(2)
    b = rng.standard_normal((64, 8))
    c = rng.standard_normal((8, 8))
    got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                jnp.asarray(c, jnp.float32),
                                backend="sharded", mesh=_mesh(1), **KNOBS)
    np.testing.assert_allclose(np.asarray(got),
                               fused_ref.unfused_gemm_spmm(a, b, c),
                               rtol=2e-3, atol=2e-3)
    entry = api.get_schedule(a, b_col=8, c_col=8, mesh=_mesh(1), **KNOBS)
    assert entry.shard is None and entry.mesh_key is None


# --------------------------------------------------------------------------
# Multi-device execution (in-process; real on the forced-8-device CI leg)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
def test_sharded_parity_multi_device(op_pair):
    if len(jax.devices()) < 2:
        pytest.skip("single-device platform; the CI multi-device leg sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    mesh = _mesh()
    a = hub_powerlaw(96, 4, seed=0)         # hub row: spill lanes cross too
    rng = np.random.default_rng(0)
    if op_pair == "spmm":
        c = rng.standard_normal((96, 8))
        got = api.tile_fused_matmul(a, a, jnp.asarray(c, jnp.float32),
                                    backend="sharded", mesh=mesh, **KNOBS)
        want = fused_ref.unfused_spmm_spmm(a, a, c)
    else:
        b = rng.standard_normal((96, 8))
        c = rng.standard_normal((8, 8))
        got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                    jnp.asarray(c, jnp.float32),
                                    backend="sharded", mesh=mesh, **KNOBS)
        want = fused_ref.unfused_gemm_spmm(a, b, c)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)
    entry = api.get_schedule(a, b_col=8, c_col=8,
                             b_is_sparse=(op_pair == "spmm"), mesh=mesh,
                             **KNOBS)
    assert entry.shard is not None
    assert api.select_backend(entry) == "sharded"
    assert entry.traffic_model["sharded"]["halo_rows"] \
        == entry.shard.halo_size


def test_auto_with_mesh_dispatches_sharded_even_unfusable():
    """``backend="auto"`` with a non-trivial mesh must honor the mesh even
    when the Eq-3 model would pick the unfused fallback on one device — a
    fusion-free schedule still distributes op-1 and wavefront-1 work."""
    if len(jax.devices()) < 2:
        pytest.skip("single-device platform")
    from repro.core.sparse.formats import CSR
    rng = np.random.default_rng(4)
    a = CSR.from_dense(rng.standard_normal((64, 64)))   # dense: fuses nothing
    entry = api.get_schedule(a, b_col=8, c_col=8, mesh=_mesh(), **KNOBS)
    assert entry.sched.fused_ratio < api.MIN_FUSED_RATIO
    assert entry.shard is not None
    assert api.select_backend(entry) == "sharded"
    b = rng.standard_normal((64, 8))
    c = rng.standard_normal((8, 8))
    got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                jnp.asarray(c, jnp.float32),
                                backend="auto", mesh=_mesh(), **KNOBS)
    np.testing.assert_allclose(np.asarray(got),
                               fused_ref.unfused_gemm_spmm(a, b, c),
                               rtol=2e-3, atol=2e-3)


def test_shard_map_shim_threads_check_kwarg():
    """The shim must accept ``check_vma`` against whichever spelling the
    installed JAX uses, on a real mesh, in both True/False modes."""
    mesh = _mesh(1)

    def f(x):
        return jax.lax.psum(x.sum(keepdims=True), "shards")

    x = jnp.arange(4, dtype=jnp.float32)
    for check in (True, False):
        g = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P(),
                      check_vma=check)
        assert float(jax.jit(g)(x)[0]) == 6.0


# --------------------------------------------------------------------------
# Forced 8-device host platform (subprocess: env must be set before jax
# initializes, so this covers multi-device even on a 1-device tier-1 run)
# --------------------------------------------------------------------------
_FORCED_SCRIPT = r"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
assert len(jax.devices()) == 8, jax.devices()
mesh = Mesh(np.array(jax.devices()), ("shards",))

# 1) the shard_map shim on a real 8-way mesh, both check modes
from repro.models.sharding import shard_map
def f(x):
    return jax.lax.psum(x.sum(keepdims=True), "shards")
for check in (True, False):
    g = shard_map(f, mesh=mesh, in_specs=(P("shards"),), out_specs=P(),
                  check_vma=check)
    out = jax.jit(g)(jnp.arange(16, dtype=jnp.float32))
    assert float(out[0]) == 120.0, out

# 2) sharded tile-fusion parity on the 8-way mesh, both op pairs
from repro.core.sparse.random import hub_powerlaw
from repro.core.tilefusion import api, fused_ref
a = hub_powerlaw(96, 4, seed=0)
rng = np.random.default_rng(0)
knobs = dict(p=2, cache_size=30_000.0, ct_size=32)
b = rng.standard_normal((96, 8)); cg = rng.standard_normal((8, 8))
got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                            jnp.asarray(cg, jnp.float32),
                            backend="sharded", mesh=mesh, **knobs)
np.testing.assert_allclose(np.asarray(got),
                           fused_ref.unfused_gemm_spmm(a, b, cg),
                           rtol=2e-3, atol=2e-3)
cs = rng.standard_normal((96, 8))
got = api.tile_fused_matmul(a, a, jnp.asarray(cs, jnp.float32),
                            backend="sharded", mesh=mesh, **knobs)
np.testing.assert_allclose(np.asarray(got),
                           fused_ref.unfused_spmm_spmm(a, a, cs),
                           rtol=2e-3, atol=2e-3)
entry = api.get_schedule(a, b_col=8, c_col=8, mesh=mesh, **knobs)
assert entry.shard.n_shards == 8

# 3) 2-D mesh cells: both layouts x both combines on a real 4x2 partition
mesh2d = Mesh(np.array(jax.devices()).reshape(4, 2), ("x", "y"))
want_g = fused_ref.unfused_gemm_spmm(a, b, cg)
outs = []
for layout in ("1d", "1.5d"):
    for combine in ("psum", "reduce_scatter"):
        got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                    jnp.asarray(cg, jnp.float32),
                                    backend="sharded", mesh=mesh2d,
                                    shard_layout=layout,
                                    shard_combine=combine, **knobs)
        np.testing.assert_allclose(np.asarray(got), want_g,
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"{layout}/{combine}")
        outs.append(np.asarray(got))
for o in outs[1:]:   # all four runs agree to roundoff, not just to the ref
    np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)
e15 = api.get_schedule(a, b_col=8, c_col=8, mesh=mesh2d,
                       shard_layout="1.5d", **knobs)
assert e15.shard.n_shards == 4 and e15.shard.n_repl == 2
assert e15.shard.layout == "1.5d"
stats = api.schedule_cache_stats()
assert stats["layout_15d"] >= 1 and stats["layout_1d"] >= 1, stats

# 4) 2.5D cell: a real 2x2x2 cube, depth-2 staged halo exchange, sync and
# async overlap both matching the oracle and each other exactly
import dataclasses
mesh3d = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("x", "y", "z"))
spec = api.FusionSpec(mesh=mesh3d, shard_layout="2.5d", overlap=False,
                      **knobs)
e25 = api.get_schedule(a, b_col=8, c_col=8, spec=spec)
assert e25.shard.n_shards == 2 and e25.shard.n_repl == 2
assert e25.shard.n_depth == 2 and e25.shard.layout == "2.5d"
pair = {}
for ov in (False, True):
    s = dataclasses.replace(spec, overlap=ov)
    got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                jnp.asarray(cg, jnp.float32),
                                backend="sharded", spec=s)
    np.testing.assert_allclose(np.asarray(got), want_g, rtol=2e-3,
                               atol=2e-3, err_msg=f"2.5d/ov={ov}")
    pair[ov] = np.asarray(got)
    got_s = api.tile_fused_matmul(a, a, jnp.asarray(cs, jnp.float32),
                                  backend="sharded", spec=s)
    np.testing.assert_allclose(np.asarray(got_s),
                               fused_ref.unfused_spmm_spmm(a, a, cs),
                               rtol=2e-3, atol=2e-3,
                               err_msg=f"2.5d-spmm/ov={ov}")
np.testing.assert_allclose(pair[True], pair[False], rtol=1e-6, atol=1e-6)
e_on = api.get_schedule(a, b_col=8, c_col=8,
                        spec=dataclasses.replace(spec, overlap=True))
assert e_on.shard.overlap and e_on.shard.n_depth == 2
stats = api.schedule_cache_stats()
assert stats["layout_25d"] >= 1, stats
assert stats["spec_entries"] >= 1, stats
print("FORCED8 OK")
"""


def test_forced_8_device_host_mesh():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(REPO_ROOT, "src"),
                    os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _FORCED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "FORCED8 OK" in out.stdout
