"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("t,j0,w,bcol,ccol", [
    (128, 16, 8, 32, 16), (256, 8, 4, 16, 64), (128, 32, 1, 8, 8)])
def test_tile_fused_gemm_spmm(t, j0, w, bcol, ccol):
    T = 3
    cols0 = jnp.asarray(RNG.integers(0, t, (T, j0, w)), jnp.int32)
    vals0 = arr((T, j0, w))
    b = arr((T * t, bcol))
    c = arr((bcol, ccol))
    d1k, rk = ops.tile_fused_gemm_spmm_wf0(cols0, vals0, b, c, t=t)
    d1r, rr = ref.tile_fused_gemm_spmm_wf0(cols0, vals0, b, c, t=t)
    np.testing.assert_allclose(np.asarray(d1k), np.asarray(d1r), **TOL[jnp.float32])
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), **TOL[jnp.float32])


@pytest.mark.parametrize("t,j0,w0,w1,n,ccol", [
    (128, 16, 8, 4, 256, 16), (64, 8, 4, 1, 128, 8)])
def test_tile_fused_spmm_spmm(t, j0, w0, w1, n, ccol):
    T = 3
    op1_cols = jnp.asarray(RNG.integers(0, n, (T, t, w1)), jnp.int32)
    op1_vals = arr((T, t, w1))
    spill = arr((T * t, ccol), scale=0.1)      # pre-accumulated hub tails
    cols0 = jnp.asarray(RNG.integers(0, t, (T, j0, w0)), jnp.int32)
    vals0 = arr((T, j0, w0))
    c = arr((n, ccol))
    d1k, rk = ops.tile_fused_spmm_spmm_wf0(op1_cols, op1_vals, spill,
                                           cols0, vals0, c, t=t)
    d1r, rr = ref.tile_fused_spmm_spmm_wf0(op1_cols, op1_vals, spill,
                                           cols0, vals0, c, t=t)
    np.testing.assert_allclose(np.asarray(d1k), np.asarray(d1r),
                               **TOL[jnp.float32])
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr),
                               **TOL[jnp.float32])


@pytest.mark.parametrize("n,w,c,block", [(256, 4, 8, 64), (512, 9, 16, 128),
                                         (128, 1, 32, 128)])
def test_spmm_ell(n, w, c, block):
    cols = jnp.asarray(RNG.integers(0, n, (n, w)), jnp.int32)
    vals = arr((n, w))
    x = arr((n, c))
    got = ops.spmm_ell(cols, vals, x, block_rows=block)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.spmm_ell(cols, vals, x)),
                               **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,f,bm,bf,act", [
    (256, 64, 512, 128, 256, "gelu"), (128, 32, 256, 128, 128, "silu")])
def test_fused_ffn(m, d, f, bm, bf, act, dtype):
    x, w1, w2 = arr((m, d), dtype), arr((d, f), dtype, 0.05), \
        arr((f, d), dtype, 0.05)
    got = ops.fused_ffn(x, w1, w2, block_m=bm, block_f=bf, act=act)
    want = ref.ffn(x, w1, w2, act=act)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("e,cap,d,f", [(4, 128, 64, 512), (2, 256, 32, 128)])
def test_fused_moe_ffn(e, cap, d, f):
    x, w1, w2 = arr((e, cap, d)), arr((e, d, f), scale=0.05), \
        arr((e, f, d), scale=0.05)
    got = ops.fused_moe_ffn(x, w1, w2, block_c=64, block_f=128)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.moe_ffn(x, w1, w2)),
                               **TOL[jnp.float32])


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 32)])
@pytest.mark.parametrize("s,dh", [(128, 32), (256, 64)])
def test_flash_attention(causal, window, s, dh):
    q, k, v = (arr((2, 2, s, dh)) for _ in range(3))
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64,
                              causal=causal, window=window)
    want = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_long_kv():
    """Decode-like: 1 query against long kv."""
    q = arr((1, 2, 128, 32))
    k = arr((1, 2, 1024, 32))
    v = arr((1, 2, 1024, 32))
    got = ops.flash_attention(q, k, v, block_q=128, block_k=256, causal=False)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_choose_kernel_tile_fits_budget():
    for bcol, ccol, j0, w in [(64, 64, 32, 8), (512, 128, 128, 64)]:
        t = ops.choose_kernel_tile(bcol, ccol, j0, w)
        elems = (t * bcol + bcol * ccol + t * ccol + 2 * j0 * w + j0 * t
                 + j0 * ccol)
        assert elems * 4 <= ops.VMEM_BUDGET
        assert t % 128 == 0
