"""HybridELL: packer parity vs the loop reference + hub-row memory bound.

The memory regression test pins the format's reason to exist: on a
power-law graph with one artificially boosted hub row, the pad-to-max
packer allocates ``n_rows × max_degree`` (the failing case, asserted
explicitly), while the hybrid pack stays width-capped and within 1.5× of
the nonzero count.
"""
import numpy as np
from _prop import given, settings, st

from repro.core.sparse.formats import (CSR, HybridELL, TileELL,
                                       hybrid_width_cap)
from repro.core.sparse.random import hub_powerlaw
from repro.core.tilefusion import build_schedule, reference, \
    to_device_schedule
from repro.core.tilefusion.cost_model import hybrid_packed_elements


def random_csr(n, density, seed):
    rng = np.random.default_rng(seed)
    m = max(int(density * n * n), 1)
    return CSR.from_coo(n, n, rng.integers(0, n, m), rng.integers(0, n, m),
                        rng.standard_normal(m))


# --------------------------------------------------------------------------
# Satellite: hub-safe memory regression (powerlaw_graph(n=8192) + hub row)
# --------------------------------------------------------------------------
def test_hybrid_pack_memory_bounded_on_hub_powerlaw():
    a = hub_powerlaw(8192, seed=0)
    counts = np.diff(a.indptr).astype(np.int64)
    max_deg = int(counts.max())
    assert max_deg >= 8192 // 2 - 1           # the hub really dominates

    # the failing case first: pad-to-max allocates n × max_degree, blowing
    # far past the 1.5×-nnz budget the hybrid format is pinned to
    pad_elements = a.n_rows * max_deg
    assert pad_elements > 1.5 * a.nnz, \
        "pad-to-max unexpectedly within budget — hub row lost?"

    cap = hybrid_width_cap(counts)            # traffic-optimal auto cap
    hell = HybridELL.from_csr_rows(a, np.arange(a.n_rows), cap=cap)
    assert hell.width <= cap                  # packed width obeys the cap
    assert hell.packed_elements() <= 1.5 * a.nnz
    # nothing lost: body nonzero slots + spill lanes account for every entry
    assert int((hell.vals != 0).sum()) + hell.n_spill == a.nnz
    # cost-model pricing agrees with the packer's actual footprint
    spill3 = hybrid_packed_elements(counts, cap) - a.n_rows * hell.width
    assert spill3 == 3 * hell.n_spill


def test_device_schedule_wf1_capped_on_hub_powerlaw():
    """The width cap reaches the schedule: wavefront-1 ELL body width stays
    at the cap and the hub tail rides the spill lanes."""
    a = hub_powerlaw(2048, seed=1)
    cap = hybrid_width_cap(np.diff(a.indptr))
    sched = build_schedule(a, b_col=16, c_col=16, p=4, cache_size=50_000.0,
                           ct_size=128, uniform_split=True)
    ds_pad = to_device_schedule(a, sched)
    ds_cap = to_device_schedule(a, sched, width_cap=cap)
    assert ds_cap.ell_cols1.shape[2] <= cap
    assert ds_cap.spill_rows1.size > 0
    assert ds_cap.ell_cols1.size + ds_cap.spill_rows1.size \
        < ds_pad.ell_cols1.size
    # the traffic model is cap-invariant (same nonzeros, same D1 spill rows)
    tm_pad = ds_pad.hbm_traffic_model(16, 16)
    tm_cap = ds_cap.hbm_traffic_model(16, 16)
    assert tm_pad["fused_bytes"] == tm_cap["fused_bytes"]
    assert tm_pad["d1_spill_rows"] == tm_cap["d1_spill_rows"]


# --------------------------------------------------------------------------
# Packer parity: vectorized HybridELL pinned by the loop reference
# --------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 150), density=st.floats(0.005, 0.1),
       seed=st.integers(0, 6), cap=st.sampled_from([None, 1, 2, 5, 1000]))
def test_hybrid_packer_matches_loop_reference(n, density, seed, cap):
    a = random_csr(n, density, seed)
    rows = np.arange(a.n_rows, dtype=np.int64)
    got = HybridELL.from_csr_rows(a, rows, cap=cap)
    want = reference.hybrid_ell_from_csr_rows_ref(a, rows, cap=cap)
    assert got.width == want.width
    assert np.array_equal(got.cols, want.cols)
    assert np.array_equal(got.vals, want.vals)
    assert np.array_equal(got.spill_rows, want.spill_rows)
    assert np.array_equal(got.spill_cols, want.spill_cols)
    assert np.array_equal(got.spill_vals, want.spill_vals)
    # uncapped hybrid degenerates to the pad-to-max TileELL body
    if cap == 1000:
        tile = TileELL.from_csr_rows(a, rows)
        assert got.n_spill == 0
        assert np.array_equal(got.cols, tile.cols)


@settings(max_examples=6, deadline=None)
@given(n=st.integers(16, 120), density=st.floats(0.01, 0.08),
       seed=st.integers(0, 5))
def test_op1_ell_matches_loop_reference_uncapped(n, density, seed):
    """The shared-packer ``_op1_ell`` reproduces the retained loop
    reference bit-for-bit in the pad-to-max case (no duplicated ELL
    logic left behind)."""
    from repro.core.tilefusion import fused_ops
    a = random_csr(n, density, seed)
    sched = build_schedule(a, b_col=8, c_col=8, p=2, cache_size=5_000.0,
                           ct_size=16, b_is_sparse=True, uniform_split=True)
    ds = to_device_schedule(a, sched)
    cols, vals, spill_flat, _, _ = fused_ops._op1_ell(a, ds)
    ref_cols, ref_vals = reference.op1_ell_ref(a, ds)
    assert spill_flat.size == 0
    assert np.array_equal(cols, ref_cols)
    assert np.array_equal(vals, ref_vals)
