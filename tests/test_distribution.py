"""Distribution layer: partitioning plans + a real (subprocess) dry-run cell.

The in-process tests run on this host's single device (divisibility guards
must degrade gracefully); the subprocess test exercises the full 512-device
multi-pod path end to end.
"""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import ARCH_NAMES, SHAPES, cells, get_config
from repro.launch import partitioning
from repro.launch.mesh import batch_axes


def test_cells_cover_assignments():
    cs = cells()
    assert len(cs) == 33   # 10 archs x 4 shapes - 7 long_500k skips
    for arch in ARCH_NAMES:
        assert any(a == arch for a, _ in cs)
    # sub-quadratic archs run long_500k
    for arch in ("xlstm-1.3b", "hymba-1.5b", "llama4-scout-17b-a16e"):
        assert (arch, "long_500k") in cs


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_input_specs_all_shapes(arch):
    cfg = get_config(arch)
    for shape_name, shape in SHAPES.items():
        if shape_name in cfg.skip_shapes:
            continue
        specs = partitioning.input_specs(arch, shape_name)
        lead = specs["tokens"] if "tokens" in specs else specs["embeds"]
        assert lead.shape[0] == shape.global_batch
        if shape.kind == "decode":
            assert lead.shape[1] == 1
        else:
            assert lead.shape[1] == shape.seq_len
        if shape.kind == "train":
            assert "labels" in specs


def test_abstract_params_no_allocation():
    cfg = get_config("qwen2-vl-72b")      # 72B params — must NOT allocate
    p = partitioning.abstract_params(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    assert total > 5e10                    # it is a ~70B-param tree
    import numpy as np_  # noqa


import numpy as np  # noqa: E402


def test_param_shardings_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("granite-moe-3b-a800m", reduced=True)
    p = partitioning.abstract_params(cfg)
    sh = partitioning.param_shardings(p, mesh)
    # every leaf got a NamedSharding and no axis oversubscription
    for leaf, s in zip(jax.tree.leaves(p), jax.tree.leaves(sh)):
        assert s.mesh.devices.size == 1


def test_batch_axes_compose_pod():
    # production meshes need 256/512 devices; batch_axes only reads names
    class _M:
        def __init__(self, names):
            self.axis_names = names
    assert batch_axes(_M(("data", "model"))) == ("data",)
    assert batch_axes(_M(("pod", "data", "model"))) == ("pod", "data")


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """Full multi-pod dry-run of the fastest cell, in a clean process."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "hymba-1.5b",
         "--shape", "long_500k", "--multi-pod", "--out",
         "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, cwd="/root/repo",
        timeout=560)
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    with open("/tmp/dryrun_test/hymba-1.5b_long_500k_512.json") as f:
        res = json.load(f)
    assert res["n_devices"] == 512
    assert res["memory_analysis"]["peak_bytes"] is not None
