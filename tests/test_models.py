"""Per-arch smoke tests: reduced configs, forward + train step + decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch import steps
from repro.models import transformer as T
from repro.optim import OptConfig, adamw

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, with_labels=True, seq=S):
    batch = {}
    if cfg.frontend == "none" or cfg.encoder_layers:
        batch["tokens"] = jax.random.randint(KEY, (B, seq), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(KEY, (B, seq, cfg.d_model))
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, seq), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, KEY)
    logits = T.forward(cfg, params, make_batch(cfg, with_labels=False))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_decreases_loss(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, KEY)
    opt_state = adamw.init(params)
    step = jax.jit(steps.make_train_step(
        cfg, OptConfig(lr=1e-2, warmup_steps=1, total_steps=20), rules=None))
    batch = make_batch(cfg)
    losses = []
    for _ in range(8):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert not np.isnan(losses).any()
    # memorizes a fixed batch (min over tail: exp-gated recurrent archs are
    # noisy step to step at this lr)
    assert min(losses[2:]) < losses[0], losses


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    """Prefix decode step-by-step == teacher-forced forward (logits agree)."""
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, KEY)
    seq = 8
    batch = make_batch(cfg, with_labels=False, seq=seq)
    full = T.forward(cfg, params, batch)
    cache = T.init_cache(cfg, B, seq)
    outs = []
    for i in range(seq):
        db = {}
        if "tokens" in batch:
            db["tokens"] = batch["tokens"][:, i:i + 1]
        else:
            db["embeds"] = batch["embeds"][:, i:i + 1]
        if cfg.encoder_layers:
            db["enc_embeds"] = batch["enc_embeds"]
        lg, cache = T.decode_step(cfg, params, db, cache, jnp.int32(i))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1).astype(jnp.float32)
    want = full.astype(jnp.float32)
    # bf16 accumulation differs between the chunked (forward) and stepwise
    # (decode) paths; verified 3e-5 agreement in f32 — tolerance covers bf16
    tol = 0.3 if cfg.block_pattern != "attn" else 0.15
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_param_count_sane():
    # full configs should be in the advertised ballpark
    approx = {
        "stablelm-1.6b": (1.0e9, 3.0e9),
        "qwen2.5-3b": (2.0e9, 4.5e9),
        "minitron-8b": (6e9, 11e9),
        "xlstm-1.3b": (0.8e9, 2.5e9),
    }
    for arch, (lo, hi) in approx.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_smaller():
    cfg = get_config("granite-moe-3b-a800m")
    assert cfg.param_count(active_only=True) < cfg.param_count()
