"""Benchmark bit-rot check: every driver runs end-to-end in smoke mode.

Each module in ``benchmarks.run.MODULES`` is executed in-process with
``REPRO_BENCH_SMOKE=1`` (tiny problem sizes, 1 rep — see benchmarks/util.py)
so a driver broken by an API change fails tier-1 instead of rotting until
someone runs the full suite.  Parametrized per module so the failure names
the driver.
"""
import importlib
import os
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO_ROOT not in sys.path:
    sys.path.insert(0, REPO_ROOT)

from benchmarks import run as bench_run  # noqa: E402


@pytest.mark.parametrize("mod_name", bench_run.MODULES)
def test_benchmark_driver_smoke(mod_name, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "1")
    from repro.core.tilefusion import api
    api.clear_schedule_cache()
    mod = importlib.import_module(f"benchmarks.{mod_name}")
    rows = mod.run()
    assert rows, f"{mod_name}.run() produced no rows"
    for row in rows:
        name, us, derived = row          # the run.py CSV contract
        assert isinstance(name, str) and name
        float(us)
        assert isinstance(derived, str)


def test_smoke_flag_scales_down(monkeypatch):
    from benchmarks import util
    monkeypatch.delenv("REPRO_BENCH_SMOKE", raising=False)
    assert not util.smoke()
    assert util.bench_n(4096) == 4096
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "1")
    assert util.smoke()
    assert util.bench_n(4096) == 256
    assert util.sweep((1, 2, 3), (1,)) == (1,)
    assert len(util.bench_suite(4096)) == 2
