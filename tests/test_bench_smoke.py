"""Benchmark bit-rot check: every driver runs end-to-end in smoke mode.

Each module in ``benchmarks.run.MODULES`` is executed in-process with
``REPRO_BENCH_SMOKE=1`` (tiny problem sizes, 1 rep — see benchmarks/util.py)
so a driver broken by an API change fails tier-1 instead of rotting until
someone runs the full suite.  Parametrized per module so the failure names
the driver.
"""
import importlib
import os
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO_ROOT not in sys.path:
    sys.path.insert(0, REPO_ROOT)

from benchmarks import run as bench_run  # noqa: E402


@pytest.mark.parametrize("mod_name", bench_run.MODULES)
def test_benchmark_driver_smoke(mod_name, monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "1")
    from repro.core.tilefusion import api
    api.clear_schedule_cache()
    mod = importlib.import_module(f"benchmarks.{mod_name}")
    rows = mod.run()
    assert rows, f"{mod_name}.run() produced no rows"
    for row in rows:
        name, us, derived = row          # the run.py CSV contract
        assert isinstance(name, str) and name
        float(us)
        assert isinstance(derived, str)


def test_parse_derived_handles_suffixes_and_text():
    d = bench_run.parse_derived(
        "speedup=39.5x;hit_rate=0.948;backend=pallas;empty=;p99_us=12.5")
    assert d["speedup"] == 39.5          # trailing 'x' stripped
    assert d["hit_rate"] == 0.948
    assert d["backend"] == "pallas"      # non-numeric stays a string
    assert d["p99_us"] == 12.5
    assert bench_run.parse_derived("") == {}


def test_check_thresholds_gates_regressions():
    rows = [("serving/stream/n192", 100.0,
             "hit_rate=0.95;rebuilds=2"),
            ("serving/incremental/n256", 50.0, "speedup=6.0x")]
    ths = [{"row": "serving/stream/", "key": "hit_rate", "min": 0.9,
            "smoke": True},
           {"row": "serving/incremental/", "key": "speedup", "min": 5.0,
            "smoke": False}]
    assert bench_run.check_thresholds(rows, ths, smoke=False) == []
    # a regression trips
    bad = [("serving/stream/n192", 100.0, "hit_rate=0.5")]
    v = bench_run.check_thresholds(bad, ths[:1], smoke=False)
    assert len(v) == 1 and "hit_rate" in v[0]
    # smoke mode skips non-smoke-safe thresholds entirely
    assert bench_run.check_thresholds(bad, ths[1:], smoke=True) == []
    # a threshold whose rows vanished is itself a violation
    v = bench_run.check_thresholds([], ths[:1], smoke=False)
    assert v and "no matching rows" in v[0]
    # a threshold keyed on a missing/non-numeric derived value trips
    v = bench_run.check_thresholds(
        [("serving/stream/n192", 1.0, "backend=xla")], ths[:1], smoke=False)
    assert v and "missing" in v[0]


def test_emit_json_roundtrip(tmp_path):
    import json
    path = tmp_path / "BENCH_test.json"
    rows = [("serving/stream/n192", 100.0, "hit_rate=0.95")]
    bench_run.emit_json(str(path), rows, meta={"smoke": True})
    doc = json.loads(path.read_text())
    assert doc["meta"]["smoke"] is True
    assert doc["rows"][0]["name"] == "serving/stream/n192"
    assert doc["rows"][0]["us"] == 100.0
    assert doc["rows"][0]["derived"]["hit_rate"] == 0.95
    assert doc["rows"][0]["derived_raw"] == "hit_rate=0.95"


def test_bench_smoke_diffs_two_newest_artifacts(tmp_path):
    """``tools/bench_smoke.diff_latest`` matches rows across the two newest
    BENCH_*.json artifacts and reports us / derived-metric movement."""
    import importlib.util
    import io
    import json
    spec = importlib.util.spec_from_file_location(
        "bench_smoke", os.path.join(REPO_ROOT, "tools", "bench_smoke.py"))
    bs = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bs)

    old = tmp_path / "BENCH_20260101.json"
    new = tmp_path / "BENCH_20260102.json"
    bench_run.emit_json(str(old),
                        [("train/gcn/fused", 100.0, "train_step_ms=0.1"),
                         ("gone/row", 1.0, "")], meta={})
    bench_run.emit_json(str(new),
                        [("train/gcn/fused", 150.0, "train_step_ms=0.15"),
                         ("fresh/row", 1.0, "")], meta={})
    os.utime(old, (1, 1))                 # force the mtime ordering
    buf = io.StringIO()
    bs.diff_latest(str(tmp_path), out=buf)
    text = buf.getvalue()
    assert "BENCH_20260101.json -> BENCH_20260102.json" in text
    assert "train/gcn/fused" in text and "+50%" in text
    assert "train_step_ms 0.1->0.15" in text
    assert "gone/row: DROPPED" in text
    assert "fresh/row: NEW" in text
    # one artifact only: no diff, no crash
    buf2 = io.StringIO()
    os.remove(old)
    bs.diff_latest(str(tmp_path), out=buf2)
    assert "fewer than two" in buf2.getvalue()


def test_shipped_thresholds_are_wellformed():
    import json
    with open(bench_run.THRESHOLDS_PATH) as f:
        ths = json.load(f)
    assert ths, "thresholds.json must gate at least one row"
    for th in ths:
        assert set(th) >= {"row", "key"}
        assert "min" in th or "max" in th


def test_smoke_flag_scales_down(monkeypatch):
    from benchmarks import util
    monkeypatch.delenv("REPRO_BENCH_SMOKE", raising=False)
    assert not util.smoke()
    assert util.bench_n(4096) == 4096
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "1")
    assert util.smoke()
    assert util.bench_n(4096) == 256
    assert util.sweep((1, 2, 3), (1,)) == (1,)
    assert len(util.bench_suite(4096)) == 2
