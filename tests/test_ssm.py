"""Chunked linear recurrence vs step-by-step reference (property tests)."""
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st

from repro.models.ssm import (chunked_linear_recurrence,
                              linear_recurrence_step)


def ref_recurrence(q, k, v, log_a, normalize=True):
    """O(S) step-by-step oracle."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if normalize:
        v = np.concatenate([v, np.ones((b, s, h, 1))], -1)
    hstate = np.zeros((b, h, dk, v.shape[-1]))
    outs = np.zeros((b, s, h, v.shape[-1]))
    for t in range(s):
        a = np.exp(log_a[:, t])[..., None, None]
        hstate = hstate * a + np.einsum("bhk,bhv->bhkv", k[:, t], v[:, t])
        outs[:, t] = np.einsum("bhk,bhkv->bhv", q[:, t], hstate)
    if normalize:
        num, den = outs[..., :dv], outs[..., dv]
        outs = num / np.maximum(np.abs(den), 1.0)[..., None]
    return outs, hstate


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 70), chunk=st.sampled_from([4, 16, 128]),
       seed=st.integers(0, 5), normalize=st.booleans())
def test_chunked_matches_stepwise(s, chunk, seed, normalize):
    rng = np.random.default_rng(seed)
    b, h, dk, dv = 2, 3, 4, 5
    q = rng.standard_normal((b, s, h, dk))
    k = rng.standard_normal((b, s, h, dk))
    v = rng.standard_normal((b, s, h, dv))
    log_a = -np.abs(rng.standard_normal((b, s, h)))  # decay <= 1
    want, want_h = ref_recurrence(q, k, v, log_a, normalize)
    got, got_h = chunked_linear_recurrence(
        jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32),
        jnp.asarray(v, jnp.float32), jnp.asarray(log_a, jnp.float32),
        chunk=chunk, normalize=normalize)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got_h), want_h, rtol=2e-3,
                               atol=2e-3)


def test_single_step_matches_chunked():
    rng = np.random.default_rng(0)
    b, h, dk, dv, s = 1, 2, 4, 4, 6
    q = rng.standard_normal((b, s, h, dk)).astype(np.float32)
    k = rng.standard_normal((b, s, h, dk)).astype(np.float32)
    v = rng.standard_normal((b, s, h, dv)).astype(np.float32)
    log_a = -np.abs(rng.standard_normal((b, s, h))).astype(np.float32)
    full, h_full = chunked_linear_recurrence(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(log_a),
        chunk=4)
    hstate = jnp.zeros((b, h, dk, dv + 1), jnp.float32)
    outs = []
    for t in range(s):
        o, hstate = linear_recurrence_step(
            jnp.asarray(q[:, t]), jnp.asarray(k[:, t]), jnp.asarray(v[:, t]),
            jnp.asarray(log_a[:, t]), hstate)
        outs.append(o)
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hstate), np.asarray(h_full),
                               rtol=2e-3, atol=2e-3)
