"""Fused executors vs oracle — the paper's correctness contract."""
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st

from repro.core.sparse.formats import CSR
from repro.core.sparse.random import powerlaw_graph
from repro.core.tilefusion import (build_schedule, fused_ops, fused_ref,
                                   to_device_schedule)


def random_csr(n, density, seed):
    rng = np.random.default_rng(seed)
    m = max(int(density * n * n), 1)
    return CSR.from_coo(n, n, rng.integers(0, n, m), rng.integers(0, n, m),
                        rng.standard_normal(m))


@settings(max_examples=12, deadline=None)
@given(n=st.integers(16, 160), seed=st.integers(0, 6),
       bcol=st.sampled_from([4, 16]), ccol=st.sampled_from([4, 8]),
       uniform=st.booleans())
def test_fused_gemm_spmm_matches_oracle(n, seed, bcol, ccol, uniform):
    a = random_csr(n, 0.05, seed)
    sched = build_schedule(a, b_col=bcol, c_col=ccol, p=2,
                           cache_size=4_000.0, ct_size=32,
                           uniform_split=uniform)
    ds = to_device_schedule(a, sched)
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((n, bcol))
    c = rng.standard_normal((bcol, ccol))
    want = fused_ref.unfused_gemm_spmm(a, b, c)
    # numpy schedule walker (checks the no-sync invariant internally)
    got_np = fused_ref.run_gemm_spmm(a, b, c, sched, check=True)
    np.testing.assert_allclose(got_np, want, rtol=1e-9, atol=1e-9)
    # jax executor
    got = fused_ops.fused_gemm_spmm(ds, jnp.asarray(b, jnp.float32),
                                    jnp.asarray(c, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(16, 120), seed=st.integers(0, 5),
       ccol=st.sampled_from([4, 8]), uniform=st.booleans())
def test_fused_spmm_spmm_matches_oracle(n, seed, ccol, uniform):
    a = random_csr(n, 0.05, seed)
    sched = build_schedule(a, b_col=ccol, c_col=ccol, p=2,
                           cache_size=4_000.0, ct_size=32, b_is_sparse=True,
                           uniform_split=uniform)
    ds = to_device_schedule(a, sched)
    rng = np.random.default_rng(seed + 100)
    c = rng.standard_normal((n, ccol))
    want = fused_ref.unfused_spmm_spmm(a, a, c)
    got_np = fused_ref.run_spmm_spmm(a, a, c, sched, check=True)
    np.testing.assert_allclose(got_np, want, rtol=1e-9, atol=1e-9)
    got = fused_ops.fused_spmm_spmm(ds, a, jnp.asarray(c, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_baselines_match_oracle():
    a = powerlaw_graph(256, 6, seed=1)
    rng = np.random.default_rng(1)
    b = rng.standard_normal((256, 16))
    c = rng.standard_normal((16, 8))
    want = fused_ref.unfused_gemm_spmm(a, b, c)
    bj, cj = jnp.asarray(b, jnp.float32), jnp.asarray(c, jnp.float32)
    ell = fused_ops.csr_to_ell(a)
    np.testing.assert_allclose(
        np.asarray(fused_ops.unfused_gemm_spmm(*ell, bj, cj)), want,
        rtol=2e-3, atol=2e-3)
    parts = fused_ops.overlapped_tiles(a, 4)
    np.testing.assert_allclose(
        np.asarray(fused_ops.overlapped_gemm_spmm(a, parts, bj, cj)), want,
        rtol=2e-3, atol=2e-3)
    waves = fused_ops.atomic_tiles(a, 4)
    np.testing.assert_allclose(
        np.asarray(fused_ops.atomic_gemm_spmm(a, waves, bj, cj)), want,
        rtol=2e-3, atol=2e-3)


def _device_schedule_with_tiles(i_starts, i_lens, t_pad):
    from repro.core.tilefusion.schedule import DeviceSchedule
    n_t = len(i_starts)
    return DeviceSchedule(
        n_i=int(sum(i_lens)), n_j=4, t_pad=t_pad,
        i_starts=np.asarray(i_starts, np.int32),
        i_lens=np.asarray(i_lens, np.int32),
        j_rows0=np.full((n_t, 1), 4, np.int32),
        ell_cols0=np.zeros((n_t, 1, 1), np.int32),
        ell_vals0=np.zeros((n_t, 1, 1), np.float32),
        j_rows1=np.full((0, 1), 4, np.int32),
        ell_cols1=np.zeros((0, 1, 1), np.int32),
        ell_vals1=np.zeros((0, 1, 1), np.float32),
    )


def test_is_uniform_empty_schedule():
    """Zero wavefront-0 tiles is trivially uniform (the old and/if-else
    precedence only got this right by accident)."""
    assert fused_ops._is_uniform(_device_schedule_with_tiles([], [], 8))


def test_is_uniform_grid_and_non_grid():
    assert fused_ops._is_uniform(
        _device_schedule_with_tiles([0, 8, 16], [8, 8, 5], 8))
    # non-contiguous starts -> not uniform
    assert not fused_ops._is_uniform(
        _device_schedule_with_tiles([0, 16], [8, 8], 8))
    # short tile in the middle -> not uniform
    assert not fused_ops._is_uniform(
        _device_schedule_with_tiles([0, 8, 16], [8, 4, 8], 8))


def test_overlapped_redundancy_positive():
    """CA-style tiling replicates work (the paper's critique)."""
    a = powerlaw_graph(512, 8, seed=2)
    red = fused_ops.overlapped_redundancy(a, 8)
    assert red > 1.0  # deps replicated across partitions
