"""RCM reordering: permutation identity + bandwidth reduction."""
import numpy as np
from _prop import given, settings, st

from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import build_schedule, fused_ref
from repro.core.tilefusion.reorder import bandwidth, permute_csr, rcm_order


def test_rcm_is_permutation():
    a = powerlaw_graph(300, 6, seed=0)
    perm = rcm_order(a)
    assert sorted(perm.tolist()) == list(range(300))


def test_rcm_reduces_bandwidth_on_shuffled_banded():
    a = banded_spd(512, 4, seed=1)
    shuffled = permute_csr(a, np.random.default_rng(0).permutation(512))
    rcm = permute_csr(shuffled, rcm_order(shuffled))
    assert bandwidth(rcm) < bandwidth(shuffled)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 5))
def test_permuted_fused_result_matches(seed):
    """P·D = (P A Pᵀ)((P B) C): run the fused schedule on the permuted
    system and un-permute — must equal the unpermuted oracle."""
    rng = np.random.default_rng(seed)
    a = powerlaw_graph(128, 5, seed=seed)
    perm = rcm_order(a)
    a_p = permute_csr(a, perm)
    b = rng.standard_normal((128, 8))
    c = rng.standard_normal((8, 4))
    want = fused_ref.unfused_gemm_spmm(a, b, c)
    sched = build_schedule(a_p, b_col=8, c_col=4, p=2, cache_size=5_000.0,
                           ct_size=32)
    d_p = fused_ref.run_gemm_spmm(a_p, b[perm], c, sched)
    got = np.empty_like(d_p)
    got[perm] = d_p          # undo: row new->old means D[perm[i]] = D_p[i]
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)
