"""RCM reordering: permutation identity + bandwidth reduction; the
rectangular-matrix guards, split row/col permutation, deque-BFS parity,
and the ``spec.reorder`` schedule transform (ISSUE 10)."""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.sparse.formats import CSR
from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import api, build_schedule, fused_ref
from repro.core.tilefusion.reorder import (bandwidth, permute_csr,
                                           rcm_order, similarity_order)


def test_rcm_is_permutation():
    a = powerlaw_graph(300, 6, seed=0)
    perm = rcm_order(a)
    assert sorted(perm.tolist()) == list(range(300))


def test_rcm_reduces_bandwidth_on_shuffled_banded():
    a = banded_spd(512, 4, seed=1)
    shuffled = permute_csr(a, np.random.default_rng(0).permutation(512))
    rcm = permute_csr(shuffled, rcm_order(shuffled))
    assert bandwidth(rcm) < bandwidth(shuffled)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 5))
def test_permuted_fused_result_matches(seed):
    """P·D = (P A Pᵀ)((P B) C): run the fused schedule on the permuted
    system and un-permute — must equal the unpermuted oracle."""
    rng = np.random.default_rng(seed)
    a = powerlaw_graph(128, 5, seed=seed)
    perm = rcm_order(a)
    a_p = permute_csr(a, perm)
    b = rng.standard_normal((128, 8))
    c = rng.standard_normal((8, 4))
    want = fused_ref.unfused_gemm_spmm(a, b, c)
    sched = build_schedule(a_p, b_col=8, c_col=4, p=2, cache_size=5_000.0,
                           ct_size=32)
    d_p = fused_ref.run_gemm_spmm(a_p, b[perm], c, sched)
    got = np.empty_like(d_p)
    got[perm] = d_p          # undo: row new->old means D[perm[i]] = D_p[i]
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def _rect(seed=0, shape=(7, 5)):
    rng = np.random.default_rng(seed)
    dense = (rng.random(shape) < 0.4) * rng.standard_normal(shape)
    return CSR.from_dense(dense)


def test_rcm_rejects_rectangular():
    """Before ISSUE 10 ``rcm_order`` walked column ids as row ids on a
    rectangular CSR — out-of-range reads or a silently wrong order.  Now
    it refuses up front."""
    with pytest.raises(ValueError, match="square"):
        rcm_order(_rect())


def test_permute_csr_symmetric_sugar_rejects_rectangular():
    """``permute_csr(a, perm)`` indexed the n_rows-sized inverse by
    column ids; on ``n_rows != n_cols`` that corrupted the pattern (or
    crashed).  The symmetric form now requires a square matrix and points
    at the split row_perm=/col_perm= API."""
    a = _rect()
    with pytest.raises(ValueError, match="row_perm"):
        permute_csr(a, np.arange(a.n_rows))


def test_permute_csr_split_perms_match_dense():
    a = _rect(seed=3, shape=(9, 6))
    rng = np.random.default_rng(1)
    rp = rng.permutation(a.n_rows)
    cp = rng.permutation(a.n_cols)
    dense = a.to_dense()
    np.testing.assert_array_equal(
        permute_csr(a, row_perm=rp).to_dense(), dense[rp])
    np.testing.assert_array_equal(
        permute_csr(a, col_perm=cp).to_dense(), dense[:, cp])
    np.testing.assert_array_equal(
        permute_csr(a, row_perm=rp, col_perm=cp).to_dense(),
        dense[rp][:, cp])


def test_permute_csr_validates_sizes():
    a = _rect(seed=4, shape=(8, 5))
    with pytest.raises(ValueError, match="row_perm"):
        permute_csr(a, row_perm=np.arange(a.n_cols))
    with pytest.raises(ValueError, match="col_perm"):
        permute_csr(a, col_perm=np.arange(a.n_rows))
    with pytest.raises(ValueError, match="not both"):
        permute_csr(banded_spd(6, 2, seed=0), np.arange(6),
                    row_perm=np.arange(6))


def _rcm_list_reference(a: CSR) -> np.ndarray:
    """The pre-ISSUE-10 list-based BFS (``pop(0)``), kept verbatim as the
    parity oracle for the deque rewrite: same seeds, same degree-sorted
    expansion, so the orders must be identical — only the complexity
    changed (O(n) per pop made near-single-component graphs O(n²))."""
    n = a.n_rows
    deg = np.diff(a.indptr)
    visited = np.zeros(n, dtype=bool)
    order = []
    for seed in np.argsort(deg, kind="stable"):
        if visited[seed]:
            continue
        queue = [int(seed)]
        visited[seed] = True
        while queue:
            u = queue.pop(0)
            order.append(u)
            nbrs = a.indices[a.indptr[u]:a.indptr[u + 1]]
            nbrs = nbrs[~visited[nbrs]]
            if nbrs.size:
                visited[nbrs] = True
                nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
                queue.extend(int(x) for x in nbrs)
    return np.asarray(order, dtype=np.int64)[::-1].copy()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 7))
def test_rcm_deque_matches_list_bfs(seed):
    a = (banded_spd(97, 3, seed=seed) if seed % 2
         else powerlaw_graph(120, 5, seed=seed))
    np.testing.assert_array_equal(rcm_order(a), _rcm_list_reference(a))


def test_similarity_order_is_permutation_and_rect_safe():
    a = _rect(seed=5, shape=(40, 23))
    perm = similarity_order(a, block=8)
    assert sorted(perm.tolist()) == list(range(40))
    # rows with identical block support land adjacent
    sq = CSR.from_dense(np.vstack([np.eye(8)[[i // 2 * 2 % 8]]
                                   for i in range(8)]))
    p = similarity_order(sq, block=1)
    key = [int(sq.indices[sq.indptr[i]]) for i in p]
    assert key == sorted(key)


def test_spec_rejects_unknown_reorder():
    with pytest.raises(ValueError, match="reorder"):
        api.FusionSpec(reorder="zigzag")


def test_reorder_auto_never_raises_modeled_traffic():
    """The Eq-3 pricing contract: a reorder="auto" entry's fused_bytes
    never exceed the identity ordering's, and an applied permutation is
    only accepted past the MIN_TRAFFIC_SAVING floor."""
    spec = api.FusionSpec(p=2, cache_size=30_000.0, ct_size=32)
    for seed in range(3):
        a = powerlaw_graph(256, 5, seed=seed)
        base = api.get_schedule(a, b_col=8, c_col=8, spec=spec)
        auto = api.get_schedule(
            a, b_col=8, c_col=8,
            spec=api.dataclasses.replace(spec, reorder="auto"))
        assert (auto.traffic_model["fused_bytes"]
                <= base.traffic_model["fused_bytes"] + 1e-9)
        if auto.reorder is not None:
            assert auto.reorder_perm is not None


def test_forced_reorder_bakes_permutation_into_entry():
    spec = api.FusionSpec(p=2, cache_size=30_000.0, ct_size=32,
                          reorder="rcm")
    a = powerlaw_graph(128, 4, seed=2)
    entry = api.get_schedule(a, b_col=8, c_col=8, spec=spec)
    assert entry.reorder == "rcm"
    perm, inv = entry.reorder_perm, entry.reorder_inv
    assert sorted(perm.tolist()) == list(range(128))
    np.testing.assert_array_equal(perm[inv], np.arange(128))
    # distinct cache entries per reorder mode: the knob is in the key
    st0 = api.schedule_cache_stats()
    api.get_schedule(a, b_col=8, c_col=8, spec=spec)
    assert api.schedule_cache_stats()["misses"] == st0["misses"]


def test_forced_reorder_rejects_rectangular_schedule():
    rect = _rect(seed=6, shape=(32, 20))
    spec = api.FusionSpec(p=2, cache_size=30_000.0, ct_size=32,
                          reorder="rcm")
    with pytest.raises(ValueError, match="square"):
        api.get_schedule(rect, b_col=8, c_col=8, spec=spec)
    # "auto" degrades gracefully instead: no permutation, no error
    auto = api.get_schedule(
        rect, b_col=8, c_col=8,
        spec=api.dataclasses.replace(spec, reorder="auto"))
    assert auto.reorder is None and auto.reorder_perm is None
