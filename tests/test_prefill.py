"""Batched prefill == token-by-token decode (all cache families)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T

# covers: full KV cache (qwen2.5), ring-buffer window with wrap (hymba,
# seq 48 > window 32), MLA latent cache (minicpm3), SSM states (xlstm)
ARCHS = ["qwen2.5-3b", "hymba-1.5b", "minicpm3-4b", "xlstm-1.3b"]


@pytest.fixture(scope="module", autouse=True)
def _fresh_jit_cache():
    # Same deterministic jaxlib CPU-compiler segfault test_serving.py
    # guards against: decode_step's scan compile crashes when it lands on
    # top of the full suite's accumulated live executables (the hetero /
    # reorder parity cells ahead of this module pushed it over the edge).
    # Dropping the process-wide jit caches first keeps the compile
    # identical to the standalone-run one.
    jax.clear_caches()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_tokenwise(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S, gen = 2, 48, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + gen), 0,
                              cfg.vocab_size)
    max_len = S + gen

    # path A: token-by-token
    cache_a = T.init_cache(cfg, B, max_len)
    for i in range(S):
        la, cache_a = T.decode_step(cfg, params, {"tokens": toks[:, i:i + 1]},
                                    cache_a, jnp.int32(i))

    # path B: batched prefill
    cache_b = T.init_cache(cfg, B, max_len)
    lb, cache_b = T.decode_step(cfg, params, {"tokens": toks[:, :S]},
                                cache_b, jnp.int32(0))
    np.testing.assert_allclose(
        np.asarray(la[:, -1].astype(jnp.float32)),
        np.asarray(lb[:, -1].astype(jnp.float32)), rtol=0.25, atol=0.25)

    # both caches must continue decoding identically
    for i in range(gen):
        step = {"tokens": toks[:, S + i:S + i + 1]}
        la, cache_a = T.decode_step(cfg, params, step, cache_a,
                                    jnp.int32(S + i))
        lb, cache_b = T.decode_step(cfg, params, step, cache_b,
                                    jnp.int32(S + i))
        np.testing.assert_allclose(
            np.asarray(la.astype(jnp.float32)),
            np.asarray(lb.astype(jnp.float32)), rtol=0.25, atol=0.25)
