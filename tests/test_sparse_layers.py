"""``sparse-band`` block pattern: the banded-decay token mixer rides the
differentiable tile-fusion seam (``tile_fused_matmul``'s custom_vjp), so a
transformer stack trains end to end through the fused GeMM-SpMM path.

Covers: the ``decay_band_csr`` operator's structure, dense equivalence of
``band_mix_apply``, forward/train through ``launch.steps`` factories, and
the documented decode limitation (no cache — serve via ``forward()``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import steps
from repro.models import ssm as S
from repro.models import transformer as T
from repro.optim import OptConfig, adamw

B, SEQ = 2, 32
KEY = jax.random.PRNGKey(0)


def _cfg():
    base = get_config("stablelm-1.6b", reduced=True)
    return dataclasses.replace(base, block_pattern="sparse-band",
                               band_window=8, band_decay=0.9,
                               ssm_head_dim=16)


def _batch(cfg, with_labels=True):
    batch = {"tokens": jax.random.randint(KEY, (B, SEQ), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(
            jax.random.fold_in(KEY, 1), (B, SEQ), 0, cfg.vocab_size)
    return batch


def test_decay_band_csr_structure():
    """A[i, j] = (1-d) d^{i-j} on a width-w lower-triangular band; every
    row sum stays below 1 so the mixer needs no normalizer."""
    seq, w, d = 16, 4, 0.8
    a = S.decay_band_csr(seq, w, d)
    dense = a.to_dense()
    assert dense.shape == (seq, seq)
    for i in range(seq):
        for j in range(seq):
            if max(0, i - w + 1) <= j <= i:
                assert dense[i, j] == pytest.approx((1 - d) * d ** (i - j))
            else:
                assert dense[i, j] == 0.0
    assert (dense.sum(axis=1) < 1.0).all()
    # memoized: the same (seq, window, decay) returns the cached object, so
    # the content-keyed schedule cache hits across layers and steps
    assert S.decay_band_csr(seq, w, d) is a
    with pytest.raises(ValueError):
        S.decay_band_csr(seq, w, 1.5)


def test_band_mix_matches_dense_reference():
    """band_mix_apply through the fused seam == the dense einsum spelling."""
    cfg = _cfg()
    p = S.band_mix_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (B, SEQ, cfg.d_model))
    a = S.decay_band_csr(SEQ, cfg.band_window, cfg.band_decay)
    got = S.band_mix_apply(p, cfg, x, a)
    a_d = jnp.asarray(a.to_dense())
    mixed = jnp.einsum("st,btk->bsk", a_d, x @ p["wv"])
    want = (mixed * jax.nn.silu(x @ p["wz"])) @ p["w_down"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_forward_shapes_no_nan():
    cfg = _cfg()
    params = T.init_params(cfg, KEY)
    logits = T.forward(cfg, params, _batch(cfg, with_labels=False))
    assert logits.shape == (B, SEQ, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_train_step_decreases_loss():
    """The stack trains through tile_fused_matmul's custom_vjp: the step
    factory jits, grads are finite, and a fixed batch memorizes."""
    cfg = _cfg()
    params = T.init_params(cfg, KEY)
    opt_state = adamw.init(params)
    step = steps.make_train_step(
        cfg, OptConfig(lr=1e-2, warmup_steps=1, total_steps=20),
        rules=None, jit=True)
    batch = _batch(cfg)
    losses = []
    for _ in range(6):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert not np.isnan(losses).any()
    assert min(losses[2:]) < losses[0], losses


def test_band_mixer_gradients_flow_through_fused_seam():
    """d loss / d wv is nonzero and finite — the sparse operand's custom_vjp
    really participates in the backward, it is not a stop-gradient."""
    cfg = _cfg()
    p = S.band_mix_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (B, SEQ, cfg.d_model))
    a = S.decay_band_csr(SEQ, cfg.band_window, cfg.band_decay)

    def loss(p):
        return (S.band_mix_apply(p, cfg, x, a) ** 2).mean()

    grads = jax.grad(loss)(p)
    for name in ("wv", "wz", "w_down"):
        g = np.asarray(grads[name], np.float32)
        assert np.isfinite(g).all(), name
        assert np.abs(g).max() > 0.0, name


def test_decode_path_raises_not_implemented():
    """sparse-band has no decode cache; both cache init and the decode step
    say so instead of silently mis-serving."""
    cfg = _cfg()
    params = T.init_params(cfg, KEY)
    with pytest.raises(NotImplementedError, match="sparse-band"):
        T.init_cache(cfg, B, SEQ)
    with pytest.raises(NotImplementedError, match="sparse-band"):
        T.decode_step(cfg, params, _batch(cfg, with_labels=False),
                      cache=None, cache_len=0)
