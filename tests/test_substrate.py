"""Data pipeline, optimizer, checkpoint, GCN, roofline parser."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import DataConfig, SyntheticStream
from repro.optim import OptConfig, adamw
from repro.roofline import collective_bytes


# ------------------------------------------------------------------ data ---
def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    s1 = SyntheticStream(cfg)
    s2 = SyntheticStream(cfg)
    b1 = s1.batch_at(7)
    b2 = s2.batch_at(7)          # fresh object, same step -> same batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch_at(8)["tokens"], b1["tokens"])


def test_data_sharding_consistent():
    """Concatenated shards == the single-host global batch (elastic safety)."""
    cfg = DataConfig(vocab_size=1000, seq_len=8, global_batch=8, seed=1)
    whole = SyntheticStream(cfg).batch_at(5)["tokens"]
    parts = [SyntheticStream(cfg, shard_index=i, shard_count=4).batch_at(5)
             ["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), whole)


# ----------------------------------------------------------------- optim ---
def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt_cfg = OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                        weight_decay=0.0)
    state = adamw.init(params)
    grad = jax.grad(lambda p: jnp.sum(p["w"] ** 2))
    for _ in range(150):
        params, state, _ = adamw.update(opt_cfg, grad(params), state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clipping():
    params = {"w": jnp.ones(4)}
    state = adamw.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, m = adamw.update(OptConfig(clip_norm=1.0), huge, state, params)
    assert float(m["grad_norm"]) > 1e8  # reported pre-clip


# ------------------------------------------------------------- checkpoint ---
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16),
                  jnp.asarray(3, jnp.int32)]}
    ckpt.save(str(tmp_path), 5, tree, extra={"step": 5})
    assert ckpt.latest_step(str(tmp_path)) == 5
    got, extra = ckpt.restore(str(tmp_path), 5, tree)
    assert extra["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_prune(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert len([d for d in os.listdir(tmp_path) if d.startswith("step_")]) == 2


@pytest.mark.slow
def test_preemption_restart_exact_resume(tmp_path):
    """Kill at step 6, restart, final state equals uninterrupted run."""
    env = dict(os.environ, PYTHONPATH="src")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen2.5-3b", "--reduced", "--steps", "8", "--batch", "2",
            "--seq", "16", "--ckpt-every", "3", "--log-every", "100"]
    d1 = str(tmp_path / "interrupted")
    r = subprocess.run(base + ["--ckpt-dir", d1, "--simulate-preemption",
                               "6"], env=env, capture_output=True, text=True,
                       cwd="/root/repo")
    assert r.returncode == 17, r.stdout + r.stderr
    r = subprocess.run(base + ["--ckpt-dir", d1], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 0, r.stdout + r.stderr
    d2 = str(tmp_path / "clean")
    r2 = subprocess.run(base + ["--ckpt-dir", d2], env=env,
                        capture_output=True, text=True, cwd="/root/repo")
    assert r2.returncode == 0
    got, _ = ckpt.restore(d1, 8, None) if False else (None, None)
    # compare final checkpoints leaf by leaf
    import glob
    import numpy as np
    f1 = sorted(glob.glob(os.path.join(d1, "step_00000008", "*.npy")))
    f2 = sorted(glob.glob(os.path.join(d2, "step_00000008", "*.npy")))
    assert f1 and len(f1) == len(f2)
    for a, b in zip(f1, f2):
        np.testing.assert_array_equal(np.load(a), np.load(b))


# --------------------------------------------------------------- roofline ---
def test_collective_bytes_parser_synthetic():
    hlo = """
  %ag = bf16[4,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[16,16]{1,0} all-reduce(%y), to_apply=%add
  %t = (f32[8]{0}, f32[8]{0}) all-to-all(%a, %b)
  %cp = u8[100]{0} collective-permute(%z)
  %not_a_collective = f32[9]{0} add(%p, %q)
"""
    got = collective_bytes(hlo)
    assert got["bytes"]["all-gather"] == 4 * 128 * 2
    assert got["bytes"]["all-reduce"] == 16 * 16 * 4
    assert got["bytes"]["all-to-all"] == 8 * 4 * 2
    assert got["bytes"]["collective-permute"] == 100
    assert got["counts"]["all-reduce"] == 1


def test_collective_bytes_parser_real_module():
    """Parse an actually-lowered sharded module."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = len(jax.devices())
    if n < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("d",))
    sh = NamedSharding(mesh, P())
    f = jax.jit(lambda x: x @ x.T, in_shardings=(sh,))
    txt = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text()
    out = collective_bytes(txt)   # no collectives on 1 device
    assert out["total_bytes"] >= 0


# -------------------------------------------------------------------- gcn ---
def test_gcn_fused_equals_unfused_and_learns():
    from repro.configs.gcn import REDUCED
    from repro.core.sparse.random import powerlaw_graph
    from repro.models.gcn import GCN
    model = GCN(REDUCED, powerlaw_graph(REDUCED.n_nodes, 6, seed=0))
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(
        (REDUCED.n_nodes, REDUCED.in_dim)), jnp.float32)
    y_f = model.forward(params, x, fused=True)
    y_u = model.forward(params, x, fused=False)
    np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_u),
                               rtol=2e-3, atol=2e-3)
    labels = jnp.asarray(rng.integers(0, REDUCED.out_dim, REDUCED.n_nodes))
    lg = jax.jit(jax.value_and_grad(lambda p: model.loss(p, x, labels)))
    p = params
    l0, _ = lg(p)
    for _ in range(20):
        loss, g = lg(p)
        p = jax.tree.map(lambda a, b: a - 0.5 * b, p, g)
    assert float(loss) < float(l0)


def test_gcn_pallas_kernel_path():
    """The paper's app through the paper's Pallas kernel (interpret mode)."""
    from repro.configs.gcn import REDUCED
    from repro.core.sparse.random import powerlaw_graph
    from repro.models.gcn import GCN
    model = GCN(REDUCED, powerlaw_graph(REDUCED.n_nodes, 6, seed=3))
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(
        (REDUCED.n_nodes, REDUCED.in_dim)), jnp.float32)
    y_pallas = model.forward(params, x, fused=True, impl="pallas")
    y_unfused = model.forward(params, x, fused=False)
    np.testing.assert_allclose(np.asarray(y_pallas), np.asarray(y_unfused),
                               rtol=2e-3, atol=2e-3)


def test_opt_shardings_zero1():
    """ZeRO-1: moments gain a data-axis dim the param sharding left free."""
    from repro.launch.partitioning import opt_shardings
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
    p_sh = {"w": NamedSharding(mesh, P(None, "model"))}
    o_sh = opt_shardings(p_sh, params, mesh)
    # dim 1 taken by model; dim 0 (size 4, divisible by data=1) gets data
    assert o_sh["w"].spec == P("data", "model")


def test_moe_shard_map_trivial_mesh_matches_local():
    """shard_map MoE on a 1x1 mesh == the local path (numerics identical)."""
    from repro.configs import get_config
    from repro.models.layers import moe_apply, moe_init
    from repro.models.sharding import ShardingRules
    cfg = get_config("granite-moe-3b-a800m", reduced=True)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_local = moe_apply(p, cfg, x, rules=None)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(batch_axes=("data",), mesh=mesh)
    with mesh:
        y_sm = moe_apply(p, cfg, x, rules=rules)
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_local),
                               rtol=2e-5, atol=2e-5)


def test_model_flops_kinds():
    from repro.configs import get_config, get_shape
    from repro.roofline import model_flops
    cfg = get_config("stablelm-1.6b")
    tr = model_flops(cfg, get_shape("train_4k"))
    pf = model_flops(cfg, get_shape("prefill_32k"))
    de = model_flops(cfg, get_shape("decode_32k"))
    assert tr == 6 / 2 * pf  # same token count, 6Nd vs 2Nd
    assert de < pf           # one token per sequence
