"""Serving-tier suite: bucketed reuse, incremental inspection, batching.

Pins the ISSUE-7 contract: (a) every tier path — cold rebuild, exact
digest hit, incremental patch — is parity-correct against the unfused
numpy oracle AND the ``fused_ref`` schedule walk (``check=True`` re-runs
the wavefront invariants on the patched schedule); (b) N distinct
patterns in K buckets occupy K cache entries with zero evictions — the
no-thrash property the content-keyed cache cannot provide; (c) the
hits/misses/incremental_patches/bucket_entries counters stay truthful
through ``clear_schedule_cache``; (d) the batching front end returns
exactly the per-request results.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse.formats import CSR, csr_content_digest
from repro.core.sparse.random import (induced_subgraph, perturb_rows,
                                      powerlaw_graph)
from repro.core.tilefusion import api, fused_ref
from repro.core.tilefusion.cost_model import serving_bucket_price
from repro.core.tilefusion.schedule import pad_device_schedule
from repro.core.tilefusion.scheduler import row_extents_for
from repro.core.tilefusion.serving import (ServingTier, csr_dirty_rows,
                                           incremental_update, pad_csr)
from repro.launch.serve import SubgraphFrontEnd

KNOBS = dict(p=2, cache_size=30_000.0, ct_size=32)


@pytest.fixture(scope="module", autouse=True)
def _fresh_jit_cache():
    # The pinned jaxlib's CPU compiler segfaults (deterministically, in
    # backend_compile) when these tests' executor compilations land on top
    # of the full suite's accumulated live executables; dropping the
    # process-wide jit caches first keeps the compile that crashes
    # identical to the standalone-run one, which is fine.
    jax.clear_caches()


@pytest.fixture(autouse=True)
def _fresh_cache():
    api.clear_schedule_cache()
    yield
    api.clear_schedule_cache()


def _graph(n=200, seed=3, avg_deg=6):
    base = powerlaw_graph(8 * n, avg_deg=avg_deg, seed=seed)
    return induced_subgraph(base, n, n)


# --------------------------------------------------------------------------
# helpers: pad_csr / csr_dirty_rows / row_extents_for / bucket pricing
# --------------------------------------------------------------------------
def test_pad_csr_is_numerical_noop():
    a = _graph(100)
    ap = pad_csr(a, 128, 128)
    assert (ap.n_rows, ap.n_cols) == (128, 128)
    assert ap.nnz == a.nnz
    want = np.zeros((128, 128))
    want[:100, :100] = a.to_dense()
    np.testing.assert_array_equal(ap.to_dense(), want)
    assert pad_csr(a, a.n_rows, a.n_cols) is a
    with pytest.raises(ValueError):
        pad_csr(a, 50, 128)


def test_csr_dirty_rows_finds_exact_delta():
    a = _graph(150)
    rng = np.random.default_rng(0)
    rows = np.sort(rng.choice(a.n_rows, 7, replace=False))
    a2 = perturb_rows(a, rows, seed=1)
    got = csr_dirty_rows(a, a2)
    # perturb_rows re-samples those rows; a re-sample may coincide with
    # the original, so dirty is a subset of the perturbed rows
    assert set(got) <= set(rows)
    assert csr_dirty_rows(a, a).size == 0
    assert csr_dirty_rows(a, pad_csr(a, 256, 256)) is None
    # value-only change (same sparsity pattern) must be caught too
    a3 = CSR(a.n_rows, a.n_cols, a.indptr, a.indices, a.data.copy())
    a3.data[a.indptr[5]] += 1.0
    np.testing.assert_array_equal(csr_dirty_rows(a, a3), [5])


def test_row_extents_for_matches_full_extents():
    a = _graph(120)
    rows = np.array([0, 3, 57, 119])
    rmin, rmax = row_extents_for(a, rows)
    dense = a.to_dense()
    for k, r in enumerate(rows):
        nz = np.nonzero(dense[r])[0]
        if nz.size:
            assert (rmin[k], rmax[k]) == (nz.min(), nz.max())
        else:
            assert (rmin[k], rmax[k]) == (a.n_cols, -1)


def test_serving_bucket_price_tradeoff():
    # tiny pad, expensive inspection -> bucket; huge pad, one-shot -> not
    cheap = serving_bucket_price(n_rows=1000, n_pad=1024, nnz=8000,
                                 b_col=32, c_col=32, expected_reuse=8.0)
    assert cheap["bucketed"]
    dear = serving_bucket_price(n_rows=10, n_pad=1024, nnz=40,
                                b_col=32, c_col=32, expected_reuse=1.0)
    assert not dear["bucketed"]
    assert dear["break_even_reuse"] < 1.0
    # more reuse always amortizes more inspection per call
    r2 = serving_bucket_price(n_rows=10, n_pad=1024, nnz=40,
                              b_col=32, c_col=32, expected_reuse=100.0)
    assert r2["inspect_elements_per_call"] < dear["inspect_elements_per_call"]


def test_pad_device_schedule_noop_and_shapes():
    a = _graph(100)
    entry = api.get_schedule(a, b_col=8, c_col=8, uniform_split=True,
                             **KNOBS)
    ds = entry.dsched
    assert pad_device_schedule(ds) is ds
    ds2 = pad_device_schedule(ds, j1_slots=10, spill_slots=40)
    assert ds2.j_rows1.size >= ds.j_rows1.size + 10
    assert ds2.spill_rows1.size == ds.spill_rows1.size + 40
    # padding is a numerical no-op
    rng = np.random.default_rng(0)
    b = rng.standard_normal((a.n_cols, 8)).astype(np.float32)
    c = rng.standard_normal((8, 8)).astype(np.float32)
    from repro.core.tilefusion import fused_ops
    got = fused_ops.fused_gemm_spmm(ds2, jnp.asarray(b), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(got),
                               fused_ref.unfused_gemm_spmm(a, b, c),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# incremental inspection
# --------------------------------------------------------------------------
def _padded_entry(a, *, b_is_sparse=False, slack=16):
    import dataclasses
    entry = api.get_schedule(a, b_col=8, c_col=8, b_is_sparse=b_is_sparse,
                             uniform_split=True, **KNOBS)
    ds = pad_device_schedule(entry.dsched, j1_slots=slack,
                             spill_slots=slack * 8)
    return dataclasses.replace(entry, dsched=ds)


@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
def test_incremental_update_parity(op_pair):
    a = _graph(160)
    entry = _padded_entry(a, b_is_sparse=(op_pair == "spmm"))
    rng = np.random.default_rng(2)
    dirty = np.sort(rng.choice(a.n_rows, 6, replace=False))
    a2 = perturb_rows(a, dirty, seed=5)
    patched = incremental_update(a, entry, a2, dirty,
                                 cache_size=KNOBS["cache_size"])
    assert patched is not None
    patched.sched.validate()
    assert patched.content_digest == csr_content_digest(a2)
    # the patched HOST schedule passes the fused_ref wavefront-invariant
    # walk (check=True) and both it and the patched DEVICE schedule agree
    # with the oracle on the new pattern
    if op_pair == "spmm":
        c = rng.standard_normal((a2.n_cols, 8))
        ref = fused_ref.run_spmm_spmm(a2, a2, c, patched.sched, check=True)
        want = fused_ref.unfused_spmm_spmm(a2, a2, c)
        from repro.core.tilefusion import fused_ops
        got = fused_ops.fused_spmm_spmm(patched.dsched, a2,
                                        jnp.asarray(c, jnp.float32))
    else:
        b = rng.standard_normal((a2.n_cols, 8))
        c = rng.standard_normal((8, 8))
        ref = fused_ref.run_gemm_spmm(a2, b, c, patched.sched, check=True)
        want = fused_ref.unfused_gemm_spmm(a2, b, c)
        from repro.core.tilefusion import fused_ops
        got = fused_ops.fused_gemm_spmm(patched.dsched,
                                        jnp.asarray(b, jnp.float32),
                                        jnp.asarray(c, jnp.float32))
    np.testing.assert_allclose(ref, want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_incremental_update_noop_and_bails():
    a = _graph(160)
    entry = _padded_entry(a)
    # empty dirty set -> the entry itself
    assert incremental_update(a, entry, a, np.array([], np.int64),
                              cache_size=KNOBS["cache_size"]) is entry
    # headroom exhausted -> None (every row dirty, way past the slack)
    all_rows = np.arange(a.n_rows)
    a2 = perturb_rows(a, all_rows, seed=1)
    assert incremental_update(a, entry, a2, all_rows,
                              cache_size=KNOBS["cache_size"]) is None
    # shape mismatch -> None
    assert incremental_update(a, entry, pad_csr(a, 256, 256),
                              np.array([0]),
                              cache_size=KNOBS["cache_size"]) is None


def test_incremental_update_moves_rows_between_wavefronts():
    # a perturbed row's new neighbors usually leave its tile -> fused row
    # must migrate wf0 -> wf1; parity above proves values, this pins the
    # structural move actually happened at least once
    a = _graph(160)
    entry = _padded_entry(a)
    fused_before = {int(j) for tl in entry.sched.wavefronts[0]
                    for j in tl.j_rows}
    rng = np.random.default_rng(3)
    cand = np.array(sorted(fused_before))
    assert cand.size, "seed graph has no fused rows; pick another seed"
    dirty = np.sort(rng.choice(cand, min(4, cand.size), replace=False))
    a2 = perturb_rows(a, dirty, seed=11)
    real_dirty = csr_dirty_rows(a, a2)
    patched = incremental_update(a, entry, a2, real_dirty,
                                 cache_size=KNOBS["cache_size"])
    assert patched is not None
    fused_after = {int(j) for tl in patched.sched.wavefronts[0]
                   for j in tl.j_rows}
    moved = fused_before - fused_after
    assert moved <= set(int(x) for x in real_dirty)
    assert moved, "no dirty fused row left wf0 (perturbation too tame)"


# --------------------------------------------------------------------------
# the tier: bucket no-thrash, counters, end-to-end parity
# --------------------------------------------------------------------------
def test_bucket_lru_never_thrashes():
    # satellite 4: N distinct patterns, K << N buckets -> exactly K cache
    # entries and zero evictions (the content-keyed cache would hold N)
    # fixed width cap so the bucket key varies only in shape ("auto" would
    # also split by the per-pattern quantized cap — still bounded, but the
    # count here would depend on degree distributions)
    tier = ServingTier(b_col=8, c_col=8, width_cap=8, **KNOBS)
    rng = np.random.default_rng(0)
    base = powerlaw_graph(2048, avg_deg=5, seed=9)
    sizes = (100, 200, 400)            # -> 3 pow2 buckets (128/256/512)
    for i in range(12):
        n = sizes[i % len(sizes)]
        a = induced_subgraph(base, (i * 37) % 1024, n)
        b = rng.standard_normal((a.n_cols, 8))
        c = rng.standard_normal((8, 8))
        d = np.asarray(tier.matmul(a, b, c))
        np.testing.assert_allclose(d, fused_ref.unfused_gemm_spmm(a, b, c),
                                   rtol=2e-3, atol=2e-3)
    st = api.schedule_cache_stats()
    assert len(tier._residents) == len(sizes)
    assert st["bucket_entries"] == len(sizes)
    assert st["entries"] == len(sizes)
    assert st["evictions"] == 0
    assert tier.stats["requests"] == 12


def test_stats_counters_and_clear():
    tier = ServingTier(b_col=8, c_col=8, **KNOBS)
    rng = np.random.default_rng(1)
    a = _graph(150)
    b = rng.standard_normal((a.n_cols, 8))
    c = rng.standard_normal((8, 8))
    tier.matmul(a, b, c)               # rebuild (miss)
    tier.matmul(a, b, c)               # exact hit
    a2 = perturb_rows(a, np.array([3, 9]), seed=2)
    tier.matmul(a2, b, c)              # incremental patch
    st = api.schedule_cache_stats()
    assert st["misses"] >= 1
    assert st["hits"] >= 2
    assert st["incremental_patches"] == 1
    assert st["bucket_entries"] == 1
    assert tier.stats == {"requests": 3, "exact_hits": 1,
                          "incremental": 1, "rebuilds": 1}
    assert tier.hit_rate() == pytest.approx(2 / 3)
    api.clear_schedule_cache()
    st = api.schedule_cache_stats()
    assert st["hits"] == st["misses"] == st["incremental_patches"] == 0
    assert st["bucket_entries"] == st["entries"] == 0


def test_tier_stream_parity_and_hit_rate():
    # a drifting stream stays correct on every path and mostly avoids the
    # inspector — the bench headline, pinned at test scale
    tier = ServingTier(b_col=8, c_col=8, **KNOBS)
    rng = np.random.default_rng(4)
    current = _graph(180)
    b = rng.standard_normal((current.n_cols, 8))
    c = rng.standard_normal((8, 8))
    for i in range(15):
        if 0 < i and i % 5 == 0:
            k = max(1, current.n_rows // 40)
            current = perturb_rows(
                current, rng.choice(current.n_rows, k, replace=False),
                seed=i)
        d = np.asarray(tier.matmul(current, b, c))
        np.testing.assert_allclose(
            d, fused_ref.unfused_gemm_spmm(current, b, c),
            rtol=2e-3, atol=2e-3, err_msg=f"request {i}")
    assert tier.stats["rebuilds"] == 1
    assert tier.stats["incremental"] >= 1
    assert tier.hit_rate() >= 0.9


def test_front_end_batched_matches_per_request():
    fe = SubgraphFrontEnd(feat_dim=4, out_dim=3, max_batch=3, **KNOBS)
    rng = np.random.default_rng(5)
    a = _graph(96)
    a2 = perturb_rows(a, np.array([1, 2]), seed=6)
    reqs = []
    for pat in (a, a, a2, a, a2):       # two patterns, interleaved
        feats = rng.standard_normal((pat.n_cols, 4))
        w = rng.standard_normal((4, 3))
        reqs.append((pat, feats, w))
        fe.submit(pat, feats, w)
    outs = fe.flush()
    assert len(outs) == len(reqs)
    for got, (pat, feats, w) in zip(outs, reqs):
        want = fused_ref.unfused_gemm_spmm(pat, feats, w)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=2e-3, atol=2e-3)
    # 5 requests, max_batch 3, two pattern groups -> fewer dispatches
    # than requests, and every logical request counted in tier stats
    assert fe.batches < len(reqs)
    assert fe.tier.stats["requests"] == len(reqs)


def test_bucket_knob_rejects_bad_compositions():
    a = _graph(100)
    with pytest.raises(ValueError):
        api.get_schedule(a, b_col=8, c_col=8, bucket=(128, 128, None),
                         autotune=True, **KNOBS)
