"""Cross-backend parity property suite — every (op-pair × backend × pattern).

One parametrized harness pins the whole dispatch matrix: both op pairs
({GeMM-SpMM, SpMM-SpMM}) × every backend ({pallas (interpret on CPU), xla,
unfused, sharded, reference}) × the pattern zoo ({banded, blockdiag,
powerlaw, empty-rows, single-hub-row, 1×1}), all asserted allclose against
the ``fused_ref`` numpy oracle.  The hybrid width cap is left at its "auto"
default so every cell — including the single-hub-row power-law case —
exercises the capped body + spill-lane path.

The ``sharded`` cell runs ``tile_fused_matmul(..., mesh=...)`` over every
device this platform has: on a plain 1-device run that exercises the
trivial-mesh fallback, and on the CI multi-device leg
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the real 8-way
shard_map partition of every cell.

Runs under ``tests/_prop.py``: real hypothesis when installed, a seeded
deterministic parametrize sweep otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st
from jax.sharding import Mesh

from repro.core.sparse.formats import CSR
from repro.core.sparse.random import (banded_spd, block_diag_noise,
                                      hub_powerlaw, powerlaw_graph)
from repro.core.tilefusion import api, fused_ref

KNOBS = dict(p=2, cache_size=30_000.0, ct_size=32)

#: Sharded cells: the flattened 1-D mesh always; 2-D factorizations (row
#: shards × column replicas under ``shard_layout="auto"``) join on the
#: forced-8-device CI leg so every op-pair × pattern also runs the 4×2
#: and 2×4 partitions.
SHARDED_CELLS = {"sharded": None}
if len(jax.devices()) >= 8:
    SHARDED_CELLS["sharded-4x2"] = (4, 2)
    SHARDED_CELLS["sharded-2x4"] = (2, 4)

#: Explicit override backends, the serving tier (bucketed + incremental
#: schedule reuse), and the numpy schedule-walking oracle.
BACKENDS = ("pallas", "xla", "unfused", *SHARDED_CELLS, "serving",
            "reference")


def _host_mesh(shape=None) -> Mesh:
    """All of this platform's devices on one 1-D axis (8 on the CI
    multi-device leg, 1 on a plain run — the trivial-mesh fallback), or a
    2-D mesh of the given shape over a device subset."""
    if shape is None:
        return Mesh(np.array(jax.devices()), ("shards",))
    n = int(np.prod(shape))
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), ("x", "y"))


def _empty_rows(n: int, seed: int) -> CSR:
    """Banded pattern with every other row (and its columns) zeroed — the
    vacuously-fusable empty-row edge the extents sentinel must handle."""
    dense = banded_spd(n, 3, seed=seed).to_dense()
    dense[::2, :] = 0.0
    return CSR.from_dense(dense)


PATTERNS = {
    "banded": lambda n, seed: banded_spd(n, 4, seed=seed),
    "blockdiag": lambda n, seed: block_diag_noise(n, block=32, seed=seed),
    "powerlaw": lambda n, seed: powerlaw_graph(n, 5, seed=seed),
    "empty-rows": _empty_rows,
    # one artificially boosted max-degree row: pad-to-max width explodes
    # and the hybrid spill lanes must carry the tail
    "single-hub-row": lambda n, seed: hub_powerlaw(n, 4, seed=seed),
    "1x1": lambda n, seed: CSR.from_dense(np.ones((1, 1))),
}


def _run_cell(a: CSR, op_pair: str, backend: str, c_col: int,
              rng) -> tuple:
    """Execute one matrix cell; returns (got, want) numpy arrays."""
    n = a.n_rows
    c_sp = rng.standard_normal((n, c_col))
    b = rng.standard_normal((n, 8))
    c_ge = rng.standard_normal((8, c_col))
    if backend == "reference":
        entry = api.get_schedule(a, b_col=c_col if op_pair == "spmm" else 8,
                                 c_col=c_col,
                                 b_is_sparse=(op_pair == "spmm"), **KNOBS)
        if op_pair == "spmm":
            got = fused_ref.run_spmm_spmm(a, a, c_sp, entry.sched, check=True)
            want = fused_ref.unfused_spmm_spmm(a, a, c_sp)
        else:
            got = fused_ref.run_gemm_spmm(a, b, c_ge, entry.sched, check=True)
            want = fused_ref.unfused_gemm_spmm(a, b, c_ge)
        return np.asarray(got), want
    if backend == "serving":
        # the tier cell runs twice: once cold (bucketed rebuild) and once
        # on a perturbed pattern (the incremental-patch path when the
        # dirty fraction allows), each against its own oracle
        from repro.core.sparse.random import perturb_rows
        from repro.core.tilefusion.serving import ServingTier
        a2 = perturb_rows(a, rng.choice(a.n_rows, 1, replace=False),
                          seed=int(rng.integers(1 << 31)))
        if op_pair == "spmm":
            tier = ServingTier(b_col=c_col, c_col=c_col, b_is_sparse=True,
                               **KNOBS)
            pairs = [(tier.matmul(a, a, c_sp),
                      fused_ref.unfused_spmm_spmm(a, a, c_sp)),
                     (tier.matmul(a2, a2, c_sp),
                      fused_ref.unfused_spmm_spmm(a2, a2, c_sp))]
        else:
            tier = ServingTier(b_col=8, c_col=c_col, **KNOBS)
            pairs = [(tier.matmul(a, b, c_ge),
                      fused_ref.unfused_gemm_spmm(a, b, c_ge)),
                     (tier.matmul(a2, b, c_ge),
                      fused_ref.unfused_gemm_spmm(a2, b, c_ge))]
        return (np.concatenate([np.asarray(g) for g, _ in pairs]),
                np.concatenate([w for _, w in pairs]))
    kwargs = dict(KNOBS)
    if backend in SHARDED_CELLS:
        kwargs["mesh"] = _host_mesh(SHARDED_CELLS[backend])
        backend = "sharded"
    if op_pair == "spmm":
        got = api.tile_fused_matmul(a, a, jnp.asarray(c_sp, jnp.float32),
                                    backend=backend, **kwargs)
        want = fused_ref.unfused_spmm_spmm(a, a, c_sp)
    else:
        got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                    jnp.asarray(c_ge, jnp.float32),
                                    backend=backend, **kwargs)
        want = fused_ref.unfused_gemm_spmm(a, b, c_ge)
    return np.asarray(got), want


@pytest.mark.parametrize("pattern", sorted(PATTERNS))
@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 4), c_col=st.sampled_from([4, 8]))
def test_parity_cell(op_pair, pattern, seed, c_col):
    a = PATTERNS[pattern](64, seed)
    rng = np.random.default_rng(1000 * seed + c_col)
    for backend in BACKENDS:
        got, want = _run_cell(a, op_pair, backend, c_col, rng)
        np.testing.assert_allclose(
            got, want, rtol=2e-3, atol=2e-3,
            err_msg=f"{op_pair}/{backend}/{pattern}/seed{seed}")


@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
def test_reduce_scatter_combine_matches_psum(op_pair):
    """The row-remapped reduce-scatter combine is numerically equivalent to
    the full-D psum it replaces: same per-row arithmetic, only the combine
    collective differs — so the two runs must agree to float roundoff, on
    every mesh this platform expresses (trivial fallback included)."""
    a = hub_powerlaw(96, 4, seed=1)        # hub row: spill lanes cross too
    rng = np.random.default_rng(1)
    outs = {}
    for combine in ("psum", "reduce_scatter"):
        mesh = _host_mesh()
        kwargs = dict(KNOBS, mesh=mesh, backend="sharded",
                      shard_combine=combine)
        if op_pair == "spmm":
            c = rng.standard_normal((96, 8))
            got = api.tile_fused_matmul(a, a, jnp.asarray(c, jnp.float32),
                                        **kwargs)
            want = fused_ref.unfused_spmm_spmm(a, a, c)
        else:
            b = rng.standard_normal((96, 8))
            c = rng.standard_normal((8, 8))
            got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                        jnp.asarray(c, jnp.float32),
                                        **kwargs)
            want = fused_ref.unfused_gemm_spmm(a, b, c)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3,
                                   atol=2e-3, err_msg=combine)
        outs[combine] = np.asarray(got)
        if len(jax.devices()) > 1:
            entry = api.get_schedule(
                a, b_col=8, c_col=8, b_is_sparse=(op_pair == "spmm"),
                mesh=mesh, shard_combine=combine, **KNOBS)
            assert entry.shard is not None
            assert entry.shard.combine == combine
        rng = np.random.default_rng(1)     # same operands for both modes
    np.testing.assert_allclose(outs["reduce_scatter"], outs["psum"],
                               rtol=1e-5, atol=1e-5)


def test_hub_row_spills_under_auto_cap():
    """The single-hub-row cell really exercises the spill lanes: the auto
    width cap is far below the hub degree, so the schedule (or the op-1
    pack) must carry spill entries — and parity above proves they land."""
    a = hub_powerlaw(96, 4, seed=0)
    api.clear_schedule_cache()
    entry = api.get_schedule(a, b_col=8, c_col=8, b_is_sparse=True, **KNOBS)
    counts = np.diff(a.indptr)
    assert entry.width_cap is not None
    assert entry.width_cap < int(counts.max())
    ds = entry.dsched
    from repro.core.tilefusion import fused_ops
    _, _, spill_flat, _, _ = fused_ops._op1_ell(a, ds,
                                                width_cap=ds.width_cap)
    assert ds.spill_rows1.size + spill_flat.size > 0


@pytest.mark.parametrize("reorder", ["auto", "rcm", "similarity"])
@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
def test_reorder_under_dispatch_parity(op_pair, reorder):
    """``spec.reorder`` is invisible to callers: the dispatch permutes the
    row-indexed operands in and the output back out, so every backend's
    result on a reordered schedule must equal the unpermuted oracle —
    including "auto" entries where the Eq-3 floor declined and no
    permutation is active."""
    import dataclasses as _dc
    spec = api.FusionSpec(**KNOBS, reorder=reorder)
    for pattern, seed in (("powerlaw", 0), ("blockdiag", 2), ("banded", 1)):
        a = PATTERNS[pattern](64, seed)
        rng = np.random.default_rng(10 * seed + 1)
        c_sp = rng.standard_normal((64, 6))
        b = rng.standard_normal((64, 8))
        c_ge = rng.standard_normal((8, 6))
        for backend in ("xla", "unfused", "auto", "pallas"):
            if op_pair == "spmm":
                got = api.tile_fused_matmul(
                    a, a, jnp.asarray(c_sp, jnp.float32), backend=backend,
                    spec=spec)
                want = fused_ref.unfused_spmm_spmm(a, a, c_sp)
            else:
                got = api.tile_fused_matmul(
                    a, jnp.asarray(b, jnp.float32),
                    jnp.asarray(c_ge, jnp.float32), backend=backend,
                    spec=spec)
                want = fused_ref.unfused_gemm_spmm(a, b, c_ge)
            np.testing.assert_allclose(
                np.asarray(got), want, rtol=2e-3, atol=2e-3,
                err_msg=f"{op_pair}/{backend}/{pattern}/reorder={reorder}")
        # pin that forced modes really ran permuted (not a no-op pass)
        if reorder != "auto":
            entry = api.get_schedule(
                a, b_col=6 if op_pair == "spmm" else 8, c_col=6,
                b_is_sparse=(op_pair == "spmm"),
                spec=_dc.replace(spec, dtype_bytes=4))
            assert entry.reorder == reorder
            assert entry.reorder_perm is not None
