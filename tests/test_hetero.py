"""Heterogeneous multi-relation fusion — the block-diagonal stack contract.

``hetero_fused_matmul`` must be indistinguishable from the per-relation
loop it replaces: same outputs (mixed rectangular relations, both op
pairs, every backend), one Algorithm-1 inspection per relation *set*
(not per call), gradients through the stacked custom_vjp, and
composition with ``spec.reorder``.  Plus the formats satellites the
stack leans on: ``block_diag_csr`` geometry and the dtype-correctness
fixes in ``from_coo`` / ``from_dense`` / ``csr_content_digest``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse.formats import (CSR, block_diag_csr,
                                       csr_content_digest)
from repro.core.sparse.random import powerlaw_graph
from repro.core.tilefusion import api, hetero

SPEC = api.FusionSpec(p=2, cache_size=30_000.0, ct_size=32)


def _rect_csr(n_rows, n_cols, seed, density=0.15):
    rng = np.random.default_rng(seed)
    dense = ((rng.random((n_rows, n_cols)) < density)
             * rng.standard_normal((n_rows, n_cols)))
    return CSR.from_dense(dense)


def _mixed_relations(c_col=6, sparse_op1=False, seed=0):
    """Four relations with distinct rectangular shapes — the shapes a
    typed hetero graph actually produces."""
    rng = np.random.default_rng(seed)
    shapes = [(40, 36), (30, 30), (24, 32), (18, 18)]
    rels = []
    for i, (nj, ni) in enumerate(shapes):
        a = _rect_csr(nj, ni, seed=seed + i)
        if sparse_op1:
            nk = 20 + 4 * i
            a1 = _rect_csr(ni, nk, seed=seed + 10 + i, density=0.2)
            c = jnp.asarray(rng.standard_normal((nk, c_col)), jnp.float32)
            rels.append((a, a1, c))
        else:
            b_col = 4 + 2 * i
            b = jnp.asarray(rng.standard_normal((ni, b_col)), jnp.float32)
            c = jnp.asarray(rng.standard_normal((b_col, c_col)),
                            jnp.float32)
            rels.append((a, b, c))
    return rels


def _loop_oracle(rels):
    outs = []
    for a, op1, c in rels:
        mid = (np.asarray(op1.to_dense()) if isinstance(op1, CSR)
               else np.asarray(op1, np.float64))
        outs.append(a.to_dense() @ (mid @ np.asarray(c, np.float64)))
    return outs


@pytest.mark.parametrize("sparse_op1", [False, True],
                         ids=["gemm_spmm", "spmm_spmm"])
@pytest.mark.parametrize("backend", ["auto", "xla", "unfused"])
def test_hetero_fused_matches_loop(backend, sparse_op1):
    rels = _mixed_relations(sparse_op1=sparse_op1)
    got = hetero.hetero_fused_matmul(rels, backend=backend, spec=SPEC)
    want = _loop_oracle(rels)
    loop = hetero.hetero_loop_matmul(rels, backend=backend, spec=SPEC)
    assert len(got) == len(rels)
    for g, l, w, (a, _, _) in zip(got, loop, want, rels):
        assert g.shape == (a.n_rows, w.shape[1])
        np.testing.assert_allclose(np.asarray(g), w, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(l), w, rtol=2e-3, atol=2e-3)


def test_hetero_single_inspection_per_relation_set():
    """The stack is the cache citizen: N relations cost ONE schedule
    entry, and repeat calls (fresh dense operands) re-stack and
    re-inspect nothing."""
    api.clear_schedule_cache()
    hetero.clear_stack_cache()
    rels = _mixed_relations()
    hetero.hetero_fused_matmul(rels, backend="xla", spec=SPEC)
    st = api.schedule_cache_stats()
    assert st["misses"] == 1
    rng = np.random.default_rng(99)
    rels2 = [(a, b, jnp.asarray(rng.standard_normal(c.shape), jnp.float32))
             for a, b, c in rels]
    hetero.hetero_fused_matmul(rels2, backend="xla", spec=SPEC)
    after = api.schedule_cache_stats()
    assert after["misses"] == 1 and after["hits"] >= st["hits"] + 1


def test_hetero_grad_matches_loop_reference():
    rels = _mixed_relations()
    adjs = [r[0] for r in rels]
    bs = [r[1] for r in rels]
    cs = [r[2] for r in rels]

    def fused_loss(bs_, cs_):
        outs = hetero.hetero_fused_matmul(
            list(zip(adjs, bs_, cs_)), backend="xla", spec=SPEC)
        return sum(jnp.sum(d ** 2) for d in outs)

    def loop_loss(bs_, cs_):
        outs = [api.tile_fused_matmul(a, b, c, backend="unfused", spec=SPEC)
                for a, b, c in zip(adjs, bs_, cs_)]
        return sum(jnp.sum(d ** 2) for d in outs)

    g_fused = jax.grad(fused_loss, argnums=(0, 1))(bs, cs)
    g_loop = jax.grad(loop_loss, argnums=(0, 1))(bs, cs)
    for got_set, want_set in zip(g_fused, g_loop):
        for g, w in zip(got_set, want_set):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-3, atol=2e-3)


def test_hetero_composes_with_reorder():
    """``spec.reorder`` applies to the stacked square pattern like any
    other — outputs still match the loop oracle."""
    import dataclasses
    rels = _mixed_relations(sparse_op1=True, seed=3)
    spec = dataclasses.replace(SPEC, reorder="rcm")
    got = hetero.hetero_fused_matmul(rels, backend="xla", spec=spec)
    for g, w in zip(got, _loop_oracle(rels)):
        np.testing.assert_allclose(np.asarray(g), w, rtol=2e-3, atol=2e-3)


def test_hetero_input_validation():
    rels = _mixed_relations()
    with pytest.raises(ValueError, match="at least one"):
        hetero.hetero_fused_matmul([])
    with pytest.raises(ValueError, match="triple"):
        hetero.hetero_fused_matmul([rels[0][:2]])
    sparse = _mixed_relations(sparse_op1=True)
    with pytest.raises(ValueError, match="mix dense and sparse"):
        hetero.hetero_fused_matmul([rels[0], sparse[1]])
    a, b, c = rels[0]
    with pytest.raises(ValueError, match="c_col"):
        hetero.hetero_fused_matmul([rels[0], (rels[1][0], rels[1][1],
                                              rels[1][2][:, :3])])
    with pytest.raises(ValueError, match="rows"):
        hetero.hetero_fused_matmul([(a, b[:-1], c)])


def test_hetero_gcn_layer_matches_reference():
    from repro.models.hetero_gcn import HeteroGCNLayer, HeteroGraph
    counts = {"user": 30, "item": 24, "tag": 12}
    graph = HeteroGraph(
        node_counts=counts,
        relations={
            ("user", "buys", "item"): _rect_csr(24, 30, seed=1),
            ("item", "bought_by", "user"): _rect_csr(30, 24, seed=2),
            ("tag", "tags", "item"): _rect_csr(24, 12, seed=3),
            ("user", "follows", "user"): _rect_csr(30, 30, seed=4),
        })
    in_dims = {"user": 8, "item": 6, "tag": 4}
    layer = HeteroGCNLayer(graph, in_dims, out_dim=5, spec=SPEC,
                           backend="xla")
    rng = np.random.default_rng(0)
    params = layer.init_params(rng)
    feats = {t: jnp.asarray(rng.standard_normal((n, in_dims[t])),
                            jnp.float32) for t, n in counts.items()}
    got = layer(params, feats)
    want = layer.reference(params, feats)
    assert sorted(got) == sorted(want)
    for t in want:
        np.testing.assert_allclose(np.asarray(got[t]), np.asarray(want[t]),
                                   rtol=2e-3, atol=2e-3)
    # and it trains: grads through the fused layer match the loop oracle
    def loss(fn, p):
        return sum(jnp.sum(v ** 2) for v in fn(p, feats).values())
    g_fused = jax.grad(lambda p: loss(layer, p))(params)
    g_ref = jax.grad(lambda p: loss(layer.reference, p))(params)
    for k in params:
        np.testing.assert_allclose(np.asarray(g_fused[k]),
                                   np.asarray(g_ref[k]),
                                   rtol=2e-3, atol=2e-3, err_msg=str(k))


def test_block_diag_csr_geometry():
    a = _rect_csr(4, 3, seed=0, density=0.6)
    b = _rect_csr(2, 5, seed=1, density=0.6)
    out = block_diag_csr([a, b])
    want = np.zeros((6, 8))
    want[:4, :3] = a.to_dense()
    want[4:, 3:] = b.to_dense()
    np.testing.assert_array_equal(out.to_dense(), want)
    # padded placement: blocks sit at their offsets, pad rows/cols empty
    out = block_diag_csr([a, b], row_sizes=[5, 4], col_sizes=[5, 6])
    want = np.zeros((9, 11))
    want[:4, :5][:, :3] = a.to_dense()
    want[5:7, 5:] [:, :5] = b.to_dense()
    np.testing.assert_array_equal(out.to_dense(), want)
    with pytest.raises(ValueError):
        block_diag_csr([a, b], row_sizes=[3, 2])


def test_from_empty_inputs_preserve_dtype():
    """Satellite: an all-zero f32 dense (or an empty COO triplet) used to
    come back float64 — poisoning dtype-keyed caches downstream."""
    empty32 = CSR.from_dense(np.zeros((3, 4), np.float32))
    assert empty32.data.dtype == np.float32
    coo32 = CSR.from_coo(3, 4, [], [], [], dtype=np.float32)
    assert coo32.data.dtype == np.float32
    # list inputs coerce, and explicit dtype= wins over the values' type
    coo = CSR.from_coo(2, 2, [0, 1], [1, 0], [1.0, 2.0], dtype=np.float32)
    assert coo.data.dtype == np.float32


def test_content_digest_distinguishes_dtype():
    """Satellite: f32 and f64 matrices with identical values used to hash
    identically (values are digested as f64) — a bf16 and an f32 serving
    stream could alias one schedule entry."""
    a32 = CSR.from_dense(np.eye(4, dtype=np.float32))
    a64 = CSR.from_dense(np.eye(4, dtype=np.float64))
    assert csr_content_digest(a32) != csr_content_digest(a64)
    # same content, same dtype -> same digest (fresh instances)
    assert (csr_content_digest(CSR.from_dense(np.eye(4, dtype=np.float32)))
            == csr_content_digest(a32))
