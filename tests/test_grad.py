"""Gradient parity through the fused path — the custom_vjp contract.

``jax.grad`` through ``tile_fused_matmul`` must match the gradient of the
dense reference product on every backend × op-pair cell: the backward is
not XLA autodiff through the executors but the api's ``custom_vjp``, whose
transposed sparse products dispatch back through the same seam (so pallas /
xla / unfused / sharded all serve the backward, off schedule entries cached
with ``transpose=True``).  Alongside parity, the suite pins the
amortization contract — forward+backward of an N-layer GCN costs exactly
one transpose inspection per (graph, layer shape), with zero re-inspections
across training steps, eager and jitted — and the dtype-pricing satellite
(bf16 operands price Eq-3 value traffic at 2 bytes, never 4).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs.gcn import GCNConfig
from repro.core.sparse.formats import CSR
from repro.core.sparse.random import banded_spd, hub_powerlaw
from repro.core.tilefusion import api, cost_model
from repro.launch.steps import make_gcn_train_step
from repro.models.gcn import GCN

KNOBS = dict(p=2, cache_size=30_000.0, ct_size=32)

#: per-dtype allclose tolerance: f32 roundoff vs bf16's ~8-bit mantissa
#: (both sides of the comparison accumulate in the operand dtype)
DTYPES = {"f32": (jnp.float32, 2e-3), "bf16": (jnp.bfloat16, 1e-1)}

BACKENDS = ("pallas", "xla", "unfused", "sharded")


def _host_mesh() -> Mesh:
    """All devices on one 1-D axis (8 on the CI multi-device leg, 1 on a
    plain run — the trivial-mesh fallback)."""
    return Mesh(np.array(jax.devices()), ("shards",))


def _empty_rows(n: int, seed: int) -> CSR:
    dense = banded_spd(n, 3, seed=seed).to_dense()
    dense[::2, :] = 0.0
    return CSR.from_dense(dense)


PATTERNS = {
    "banded": lambda n, seed: banded_spd(n, 4, seed=seed),
    "powerlaw-hub": lambda n, seed: hub_powerlaw(n, 4, seed=seed),
    "empty-rows": _empty_rows,
}


def _grad_cell(a: CSR, op_pair: str, backend: str, dtype) -> tuple:
    """One grad-parity cell: (fused grads, dense-reference grads)."""
    rng = np.random.default_rng(7)
    n = a.n_rows
    ad = jnp.asarray(a.to_dense(), dtype)
    kwargs = dict(KNOBS)
    if backend == "sharded":
        kwargs["mesh"] = _host_mesh()
    # a fixed random cotangent (sum(w * D)) exercises the full backward
    # without the squared-loss magnitude blowup bf16 can't resolve
    if op_pair == "spmm":
        c = jnp.asarray(rng.standard_normal((n, 6)), dtype)
        w = jnp.asarray(rng.standard_normal((n, 6)), dtype)
        got = jax.grad(lambda c_: jnp.sum(
            w * api.tile_fused_matmul(a, a, c_, backend=backend,
                                      **kwargs)))(c)
        want = jax.grad(lambda c_: jnp.sum(w * (ad @ (ad @ c_))))(c)
        return (np.asarray(got, np.float32),), (np.asarray(want,
                                                           np.float32),)
    b = jnp.asarray(rng.standard_normal((n, 8)), dtype)
    c = jnp.asarray(rng.standard_normal((8, 6)), dtype)
    w = jnp.asarray(rng.standard_normal((n, 6)), dtype)
    got = jax.grad(lambda b_, c_: jnp.sum(
        w * api.tile_fused_matmul(a, b_, c_, backend=backend, **kwargs)),
        argnums=(0, 1))(b, c)
    want = jax.grad(lambda b_, c_: jnp.sum(w * (ad @ (b_ @ c_))),
                    argnums=(0, 1))(b, c)
    return (tuple(np.asarray(g, np.float32) for g in got),
            tuple(np.asarray(g, np.float32) for g in want))


@pytest.mark.parametrize("dtype_name", sorted(DTYPES))
@pytest.mark.parametrize("pattern", sorted(PATTERNS))
@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
def test_grad_parity_cell(op_pair, pattern, dtype_name):
    dtype, tol = DTYPES[dtype_name]
    a = PATTERNS[pattern](64, 3)
    for backend in BACKENDS:
        got, want = _grad_cell(a, op_pair, backend, dtype)
        for g, r in zip(got, want):
            np.testing.assert_allclose(
                g, r, rtol=tol, atol=tol,
                err_msg=f"{op_pair}/{backend}/{pattern}/{dtype_name}")


def test_backward_served_by_cached_transpose_schedule():
    """The backward's schedule is a real cache citizen: one grad call mints
    transpose entries (``transpose_entries`` >= 1), repeat calls hit."""
    api.clear_schedule_cache()
    a = banded_spd(64, 4, seed=0)
    b = jnp.ones((64, 8), jnp.float32)
    c = jnp.ones((8, 4), jnp.float32)

    def loss(b_, c_):
        return jnp.sum(api.tile_fused_matmul(a, b_, c_, backend="xla",
                                             **KNOBS) ** 2)

    jax.grad(loss, argnums=(0, 1))(b, c)
    stats = api.schedule_cache_stats()
    assert stats["transpose_entries"] >= 1
    misses = stats["misses"]
    jax.grad(loss, argnums=(0, 1))(b, c)
    after = api.schedule_cache_stats()
    assert after["misses"] == misses
    assert after["transpose_entries"] == stats["transpose_entries"]


@pytest.mark.parametrize("jit", [False, True])
def test_gcn_train_one_transpose_inspection_per_shape(jit):
    """Forward+backward of an N-layer GCN costs exactly one transpose
    inspection per (graph, layer shape) — the model has two distinct
    (b_col, c_col) layer shapes, so exactly two transpose entries — and
    further training steps re-inspect nothing, eager and jitted alike."""
    api.clear_schedule_cache()
    cfg = GCNConfig(n_nodes=96, in_dim=16, hidden_dim=16, out_dim=8,
                    n_layers=3)
    adj = banded_spd(cfg.n_nodes, 4, seed=1)
    model = GCN(cfg, adj, **{k: v for k, v in KNOBS.items()})
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((cfg.n_nodes, cfg.in_dim)),
                    jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.out_dim, cfg.n_nodes))
    params = model.init_params(jax.random.PRNGKey(0))

    step = make_gcn_train_step(model, lr=0.1, jit=jit)
    params, loss0 = step(params, x, y)
    stats = api.schedule_cache_stats()
    # layer shapes: (16,16) ×2 and (16,8) → two distinct transposed keys
    assert stats["transpose_entries"] == 2
    misses = stats["misses"]
    for _ in range(3):
        params, loss = step(params, x, y)
    after = api.schedule_cache_stats()
    assert after["misses"] == misses, "training steps re-inspected"
    assert after["transpose_entries"] == 2
    assert float(loss) < float(loss0), "SGD on fused grads went uphill"


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_normalize_adjacency_preserves_dtype(dtype):
    """``normalize_adjacency`` must not silently upcast the adjacency to
    float64 (the degree arithmetic runs in f64): a float32 graph stays
    float32 all the way into the schedule cache, so nothing downstream
    hashes/packs a wide matrix that gets downcast per call."""
    from repro.models.gcn import normalize_adjacency
    a = banded_spd(32, 3, seed=0)
    a = CSR(a.n_rows, a.n_cols, a.indptr, a.indices,
            a.data.astype(dtype))
    out = normalize_adjacency(a)
    assert out.data.dtype == np.dtype(dtype)
    # and the normalization itself is right in either dtype
    deg = np.maximum(np.diff(a.indptr), 1).astype(np.float64)
    dinv = 1.0 / np.sqrt(deg)
    rows = np.repeat(np.arange(a.n_rows), np.diff(a.indptr))
    want = a.data.astype(np.float64) * dinv[rows] * dinv[a.indices]
    np.testing.assert_allclose(out.data.astype(np.float64), want,
                               rtol=1e-6)


def test_operand_dtype_bytes():
    assert cost_model.operand_dtype_bytes(jnp.ones((2,), jnp.float32)) == 4
    assert cost_model.operand_dtype_bytes(jnp.ones((2,), jnp.bfloat16)) == 2
    assert cost_model.operand_dtype_bytes(None, jnp.ones((2,),
                                                         jnp.float16)) == 2
    assert cost_model.operand_dtype_bytes() == 4


def test_dtype_pricing_splits_value_and_index_traffic():
    """bf16 entries price value traffic at 2 bytes while index traffic
    stays at 4 — so the bf16 fused-bytes prediction sits strictly between
    half the f32 one (all-value) and the f32 one (all-index)."""
    api.clear_schedule_cache()
    a = banded_spd(96, 4, seed=2)
    e32 = api.get_schedule(a, b_col=8, c_col=8, dtype_bytes=4, **KNOBS)
    e16 = api.get_schedule(a, b_col=8, c_col=8, dtype_bytes=2, **KNOBS)
    f32b, f16b = (e32.traffic_model["fused_bytes"],
                  e16.traffic_model["fused_bytes"])
    assert 0.5 * f32b < f16b < f32b
    assert e32.dtype_bytes == 4 and e16.dtype_bytes == 2
    # distinct cache entries: the second inspection was a miss, not a hit
    assert api.schedule_cache_stats()["misses"] >= 2
    # and the dispatch derives the key from the operands: a bf16 forward
    # hits the dtype_bytes=2 entry instead of minting a third
    misses = api.schedule_cache_stats()["misses"]
    api.tile_fused_matmul(a, jnp.ones((96, 8), jnp.bfloat16),
                          jnp.ones((8, 8), jnp.bfloat16), backend="xla",
                          **KNOBS)
    assert api.schedule_cache_stats()["misses"] == misses


def test_grad_under_mesh_trains():
    """The GCN training loop differentiates under a non-trivial ``mesh=``:
    the backward dispatches through the sharded executors (or their
    trivial-mesh fallback on a 1-device run) and still matches the dense
    reference."""
    cfg = GCNConfig(n_nodes=64, in_dim=8, hidden_dim=8, out_dim=4,
                    n_layers=2)
    adj = banded_spd(cfg.n_nodes, 4, seed=3)
    model = GCN(cfg, adj, **{k: v for k, v in KNOBS.items()})
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((cfg.n_nodes, cfg.in_dim)),
                    jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.out_dim, cfg.n_nodes))
    params = model.init_params(jax.random.PRNGKey(1))
    mesh = _host_mesh()
    g_mesh = jax.grad(lambda p: model.loss(p, x, y, backend="sharded",
                                           mesh=mesh))(params)
    g_ref = jax.grad(lambda p: model.loss(p, x, y, backend="xla"))(params)
    for gm, gr in zip(g_mesh, g_ref):
        np.testing.assert_allclose(np.asarray(gm), np.asarray(gr),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("reorder", ["rcm", "similarity", "auto"])
@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
def test_grad_parity_through_reordered_schedule(op_pair, reorder):
    """``jax.grad`` through a ``spec.reorder`` schedule matches the dense
    reference: the in/out permutations are linear (``jnp.take``), so the
    custom_vjp backward — served from the transpose-keyed entry, itself
    built under the same reorder knob — needs no special-casing."""
    from repro.core.sparse.random import powerlaw_graph
    a = powerlaw_graph(64, 5, seed=9)
    spec = api.FusionSpec(**KNOBS, reorder=reorder)
    rng = np.random.default_rng(11)
    ad = jnp.asarray(a.to_dense(), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 6)), jnp.float32)
    for backend in ("xla", "unfused", "auto"):
        if op_pair == "spmm":
            c = jnp.asarray(rng.standard_normal((64, 6)), jnp.float32)
            got = jax.grad(lambda c_: jnp.sum(
                w * api.tile_fused_matmul(a, a, c_, backend=backend,
                                          spec=spec)))(c)
            want = jax.grad(lambda c_: jnp.sum(w * (ad @ (ad @ c_))))(c)
            pairs = [(got, want)]
        else:
            b = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
            c = jnp.asarray(rng.standard_normal((8, 6)), jnp.float32)
            got = jax.grad(lambda b_, c_: jnp.sum(
                w * api.tile_fused_matmul(a, b_, c_, backend=backend,
                                          spec=spec)),
                argnums=(0, 1))(b, c)
            want = jax.grad(lambda b_, c_: jnp.sum(w * (ad @ (b_ @ c_))),
                            argnums=(0, 1))(b, c)
            pairs = list(zip(got, want))
        for g, r in pairs:
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(r), rtol=2e-3, atol=2e-3,
                err_msg=f"{op_pair}/{backend}/reorder={reorder}")
