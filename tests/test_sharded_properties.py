"""Property-based distributed-parity fleet for the sharded dispatch.

Three property families over random sparsity patterns × mesh shapes (1-D
and 2-D) × combine modes × dtypes, run through the ``tests/_prop.py``
harness (real hypothesis when installed, the seeded deterministic sweep
otherwise — either way every environment draws the same cases):

  structure   the row-block tile partition is disjoint + exhaustive, and
              the reduce-scatter ownership permutation is a bijection of
              D's rows onto per-shard blocks (each row owned by exactly
              the shard that writes it).  Pure numpy — runs with
              *synthetic* shard counts on any host, no devices needed.
  halo        the schedule's halo index set equals a brute-force
              recomputation of the wavefront-1 dependency rows straight
              from the CSR (the ``wf1_dep_rows`` contract re-derived
              independently).
  parity      sharded execution over every mesh shape this platform can
              express (all-device 1-D; 2-D splits when ≥4 devices; the
              2×2×2 cube when ≥8) × {psum, reduce_scatter} ×
              {1d, 1.5d, 2.5d, auto} × {sync, overlap} × dtypes equals
              the single-device ``fused_ref`` oracle, and the async
              halo-overlap path equals the synchronous path on the SAME
              partition (tight tolerance — overlap re-routes the exchange,
              it must not change the math).  On a 1-device run this
              exercises the trivial-mesh fallback; the CI multi-device leg
              (``--xla_force_host_platform_device_count=8``) runs the
              real 8-way partitions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st
from jax.sharding import Mesh

from repro.core.sparse.formats import CSR
from repro.core.sparse.random import (banded_spd, block_diag_noise,
                                      hub_powerlaw, powerlaw_graph)
from repro.core.tilefusion import api, fused_ref, sharded

SPEC = api.FusionSpec(p=2, cache_size=30_000.0, ct_size=32)


def _empty_rows(n: int, seed: int) -> CSR:
    dense = banded_spd(n, 3, seed=seed).to_dense()
    dense[::2, :] = 0.0
    return CSR.from_dense(dense)


PATTERNS = {
    "banded": lambda n, seed: banded_spd(n, 4, seed=seed),
    "blockdiag": lambda n, seed: block_diag_noise(n, block=16, seed=seed),
    "powerlaw": lambda n, seed: powerlaw_graph(n, 5, seed=seed),
    "empty-rows": _empty_rows,
    "single-hub-row": lambda n, seed: hub_powerlaw(n, 4, seed=seed),
}

#: Mesh shapes this platform can express: the flattened 1-D mesh always,
#: 2-D factorizations when the (possibly CI-forced) device count allows,
#: and the 2×2×2 cube (the 2.5D depth rung) on an 8-device leg.
MESH_SHAPES = [(len(jax.devices()),)]
if len(jax.devices()) >= 4:
    MESH_SHAPES.append((len(jax.devices()) // 2, 2))
if len(jax.devices()) >= 8:
    MESH_SHAPES.append((2, 4))
    MESH_SHAPES.append((2, 2, 2))

#: Per-dtype tolerances: bf16's 8-bit mantissa accumulates ~0.4% per term
#: over ~100-term hub rows — loose bounds still catch structural parity
#: bugs (a dropped halo row or misrouted owner block is an O(1) error).
_TOL = {"float32": 2e-3, "bfloat16": 1.5e-1}


def _mesh(shape) -> Mesh:
    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, ("x", "y", "z")[: len(shape)])


def _build(pattern: str, n: int, seed: int, n_shards: int, n_repl: int,
           spmm: bool):
    a = PATTERNS[pattern](n, seed)
    entry = api.get_schedule(a, b_col=8, c_col=8, b_is_sparse=spmm,
                             spec=SPEC)
    shard = sharded.build_sharded_schedule(
        a, entry.sched, entry.dsched, (n_shards, n_repl), b_col=8, c_col=8,
        b_is_sparse=spmm, width_cap=entry.width_cap,
        layout="1.5d" if n_repl > 1 else "1d")
    return a, entry, shard


# --------------------------------------------------------------------------
# Structure: partition disjoint + exhaustive, ownership a bijection
# --------------------------------------------------------------------------
@settings(max_examples=14, deadline=None)
@given(pattern=st.sampled_from(sorted(PATTERNS)), n=st.integers(9, 150),
       seed=st.integers(0, 5), n_shards=st.integers(2, 9),
       n_repl=st.integers(1, 3), spmm=st.booleans())
def test_partition_disjoint_exhaustive(pattern, n, seed, n_shards, n_repl,
                                       spmm):
    a, entry, shard = _build(pattern, n, seed, n_shards, n_repl, spmm)
    assert shard is not None, "uniform schedules must always shard"
    ds = entry.dsched
    # --- wf0 tile partition: contiguous, disjoint, exhaustive ---
    assert shard.tile_bounds.shape == (n_shards + 1,)
    assert shard.tile_bounds[0] == 0
    assert shard.tile_bounds[-1] == ds.n_tiles0
    assert (np.diff(shard.tile_bounds) >= 0).all()
    assert shard.shard_tile_counts().sum() == ds.n_tiles0
    # every real tile id appears exactly once in the stacked map
    real = shard.tile_map[shard.tile_map < ds.n_tiles0]
    np.testing.assert_array_equal(np.sort(real), np.arange(ds.n_tiles0))
    # --- output ownership: a bijection of D rows onto per-shard blocks ---
    perm = shard.out_perm
    r_per = shard.rows_per_shard
    assert perm.shape == (ds.n_j,)
    assert np.unique(perm).size == ds.n_j          # injective => bijection
    owner = perm // r_per
    assert ((owner >= 0) & (owner < n_shards)).all()
    assert (perm % r_per < r_per).all()
    counts = shard.shard_owned_counts()
    assert counts.sum() == ds.n_j
    assert counts.max() == 0 or counts.max() <= r_per
    # local positions are dense ranks: block s holds counts[s] rows packed
    # from its base (the reduce-scatter block is gap-free)
    for s in range(n_shards):
        block = np.sort(perm[owner == s]) - s * r_per
        np.testing.assert_array_equal(block, np.arange(counts[s]))
    # --- stacked out_rows land inside their shard's real block ---
    for stacked, t_per in ((shard.out_rows0, shard.tiles_per_shard),
                           (shard.out_rows1, shard.wf1_per_shard)):
        if not stacked.size or not t_per:
            continue
        by_shard = stacked.reshape(n_shards, -1)
        for s in range(n_shards):
            loc = by_shard[s][by_shard[s] < r_per]     # r_per = pad slot
            assert (loc < max(counts[s], 1)).all()


@settings(max_examples=10, deadline=None)
@given(pattern=st.sampled_from(sorted(PATTERNS)), n=st.integers(9, 150),
       seed=st.integers(0, 5), n_shards=st.integers(2, 9))
def test_ownership_matches_write_sets(pattern, n, seed, n_shards):
    """Each shard's owned rows are exactly the D rows its wf0 + wf1 tiles
    write — the disjointness the reduce-scatter combine rests on."""
    a, entry, shard = _build(pattern, n, seed, n_shards, 1, False)
    ds = entry.dsched
    owner = shard.out_perm // shard.rows_per_shard
    # wf0: stacked fused rows of shard s must be owned by s
    jr0 = shard.j_rows0.reshape(n_shards, -1)
    jr1 = shard.j_rows1.reshape(n_shards, -1) if shard.wf1_per_shard \
        else np.full((n_shards, 0), ds.n_j)
    written = np.full(ds.n_j, -1, dtype=np.int64)
    for s in range(n_shards):
        for jr in (jr0[s], jr1[s]):
            rows = jr[jr < ds.n_j]
            assert (owner[rows] == s).all()
            written[rows] = s
    assert (written >= 0).all(), "every D row written by some shard"
    # spill lanes are co-located with their target row's owner
    sp = shard.spill_rows1[shard.spill_rows1 < ds.n_j]
    if sp.size:
        sp_shard = np.repeat(np.arange(n_shards),
                             shard.spill_per_shard)[
            shard.spill_rows1 < ds.n_j]
        assert (owner[sp] == sp_shard).all()


# --------------------------------------------------------------------------
# Halo: schedule halo == brute-force wavefront-1 dependency recomputation
# --------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(pattern=st.sampled_from(sorted(PATTERNS)), n=st.integers(9, 150),
       seed=st.integers(0, 5), n_shards=st.integers(2, 9),
       spmm=st.booleans())
def test_halo_equals_bruteforce_deps(pattern, n, seed, n_shards, spmm):
    a, entry, shard = _build(pattern, n, seed, n_shards, 1, spmm)
    deps = []
    for tl in entry.sched.wavefronts[1]:
        for j in np.asarray(tl.j_rows):
            lo, hi = int(a.indptr[j]), int(a.indptr[j + 1])
            cols = a.indices[lo:hi]
            vals = a.data[lo:hi]
            deps.append(cols[vals != 0])
    want = (np.unique(np.concatenate(deps)).astype(np.int64)
            if deps and sum(d.size for d in deps)
            else np.zeros(0, np.int64))
    np.testing.assert_array_equal(shard.halo_rows, want)
    # and the send tables cover the halo exactly once
    pos = shard.send_pos[shard.send_pos < shard.halo_size]
    np.testing.assert_array_equal(np.sort(pos.reshape(-1)),
                                  np.arange(shard.halo_size))


# --------------------------------------------------------------------------
# Execution parity: sharded ≡ fused_ref oracle over meshes × modes × dtypes
# --------------------------------------------------------------------------
@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
@settings(max_examples=6, deadline=None)
@given(pattern=st.sampled_from(sorted(PATTERNS)), seed=st.integers(0, 3),
       mesh_shape=st.sampled_from(MESH_SHAPES),
       combine=st.sampled_from(["auto", "psum", "reduce_scatter"]),
       layout=st.sampled_from(["auto", "1d", "1.5d", "2.5d"]),
       overlap=st.booleans(),
       dtype=st.sampled_from(sorted(_TOL)))
def test_sharded_parity_vs_oracle(op_pair, pattern, seed, mesh_shape,
                                  combine, layout, overlap, dtype):
    a = PATTERNS[pattern](64, seed)
    rng = np.random.default_rng(7000 + 17 * seed)
    mesh = _mesh(mesh_shape)
    jdt = jnp.dtype(dtype)
    tol = _TOL[dtype]
    spec = dataclasses.replace(SPEC, mesh=mesh, shard_combine=combine,
                               shard_layout=layout, overlap=overlap)
    if op_pair == "spmm":
        c = jnp.asarray(rng.standard_normal((64, 8)), jdt)
        got = api.tile_fused_matmul(a, a, c, backend="sharded", spec=spec)
        want = fused_ref.unfused_spmm_spmm(
            a, a, np.asarray(c, np.float64))
    else:
        b = jnp.asarray(rng.standard_normal((64, 8)), jdt)
        c = jnp.asarray(rng.standard_normal((8, 8)), jdt)
        got = api.tile_fused_matmul(a, b, c, backend="sharded", spec=spec)
        want = fused_ref.unfused_gemm_spmm(
            a, np.asarray(b, np.float64), np.asarray(c, np.float64))
    np.testing.assert_allclose(
        np.asarray(got, np.float64), want, rtol=tol, atol=tol,
        err_msg=f"{op_pair}/{pattern}/seed{seed}/{mesh_shape}/"
                f"{combine}/{layout}/ov{int(overlap)}/{dtype}")


# --------------------------------------------------------------------------
# Overlap ≡ sync: the async exchange re-routes the halo, not the math
# --------------------------------------------------------------------------
@pytest.mark.parametrize("op_pair", ["gemm", "spmm"])
@settings(max_examples=8, deadline=None)
@given(pattern=st.sampled_from(sorted(PATTERNS)), seed=st.integers(0, 3),
       mesh_shape=st.sampled_from(MESH_SHAPES),
       combine=st.sampled_from(["auto", "psum", "reduce_scatter"]),
       layout=st.sampled_from(["auto", "1d", "1.5d", "2.5d"]))
def test_overlap_equals_sync(op_pair, pattern, seed, mesh_shape, combine,
                             layout):
    """Same partition, halo exchange issued async vs eagerly: outputs must
    agree to float32 roundoff — overlap changes WHEN the collective runs
    and how wf1 indexes its result, never the values exchanged."""
    a = PATTERNS[pattern](64, seed)
    rng = np.random.default_rng(9000 + 31 * seed)
    mesh = _mesh(mesh_shape)
    s_off = dataclasses.replace(SPEC, mesh=mesh, shard_combine=combine,
                                shard_layout=layout, overlap=False)
    s_on = dataclasses.replace(s_off, overlap=True)
    if op_pair == "spmm":
        c = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
        ops = (a, a, c)
    else:
        b = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
        ops = (a, b, c)
    off = api.tile_fused_matmul(*ops, backend="sharded", spec=s_off)
    on = api.tile_fused_matmul(*ops, backend="sharded", spec=s_on)
    np.testing.assert_allclose(
        np.asarray(on, np.float64), np.asarray(off, np.float64),
        rtol=1e-6, atol=1e-6,
        err_msg=f"{op_pair}/{pattern}/seed{seed}/{mesh_shape}/"
                f"{combine}/{layout}")
