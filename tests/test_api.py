"""Unified dispatch API: inspector cache, backend overrides, cost model."""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse.formats import CSR
from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import api, fused_ref


@pytest.fixture(autouse=True)
def fresh_cache():
    api.clear_schedule_cache()
    yield
    api.clear_schedule_cache()


def test_cache_hit_identical_pattern_builds_once():
    a = banded_spd(256, 4, seed=0)
    e1 = api.get_schedule(a, b_col=16, c_col=16)
    stats = api.schedule_cache_stats()
    assert (stats["hits"], stats["misses"], stats["entries"]) == (0, 1, 1)
    e2 = api.get_schedule(a, b_col=16, c_col=16)
    assert e2 is e1                       # schedule built exactly once
    assert api.schedule_cache_stats()["hits"] == 1
    # same content in a fresh CSR object still hits (content-keyed)
    a_copy = CSR(a.n_rows, a.n_cols, a.indptr.copy(), a.indices.copy(),
                 a.data.copy())
    assert api.get_schedule(a_copy, b_col=16, c_col=16) is e1
    # a different cache budget is a different schedule
    api.get_schedule(a, b_col=16, c_col=16, cache_size=5_000.0)
    assert api.schedule_cache_stats()["misses"] == 2


def test_cache_distinguishes_values_same_pattern():
    a = banded_spd(128, 4, seed=1)
    e1 = api.get_schedule(a, b_col=8, c_col=8)
    a_scaled = CSR(a.n_rows, a.n_cols, a.indptr, a.indices, a.data * 2.0)
    e2 = api.get_schedule(a_scaled, b_col=8, c_col=8)
    assert e2 is not e1                   # DeviceSchedule bakes in values


def test_matmul_calls_amortize_inspection():
    a = powerlaw_graph(256, 5, seed=3)
    b = jnp.ones((256, 8), jnp.float32)
    c = jnp.ones((8, 8), jnp.float32)
    for _ in range(4):
        api.tile_fused_matmul(a, b, c, backend="xla")
    stats = api.schedule_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 3


def test_backend_overrides_agree_gemm_spmm():
    a = banded_spd(512, 6, seed=1)
    rng = np.random.default_rng(1)
    b = rng.standard_normal((512, 32))
    c = rng.standard_normal((32, 16))
    want = fused_ref.unfused_gemm_spmm(a, b, c)
    bj = jnp.asarray(b, jnp.float32)
    cj = jnp.asarray(c, jnp.float32)
    for backend in api.BACKENDS:
        got = api.tile_fused_matmul(a, bj, cj, backend=backend,
                                    cache_size=50_000.0, ct_size=128)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3,
                                   atol=2e-3, err_msg=backend)


def test_backend_overrides_agree_spmm_spmm():
    a = powerlaw_graph(256, 5, seed=2)
    rng = np.random.default_rng(2)
    c = rng.standard_normal((256, 8))
    want = fused_ref.unfused_spmm_spmm(a, a, c)
    cj = jnp.asarray(c, jnp.float32)
    for backend in api.BACKENDS:          # pallas runs interpret off-TPU
        got = api.tile_fused_matmul(a, a, cj, backend=backend,
                                    cache_size=20_000.0, ct_size=64)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3,
                                   atol=2e-3, err_msg=backend)


def test_select_backend_pallas_spmm_spmm(monkeypatch):
    """Acceptance: an SpMM-SpMM schedule dispatches to the Pallas kernel on
    capable hardware (interpret mode stands in for TPU in CI), and the auto
    path executes it end to end."""
    monkeypatch.setenv("PALLAS_INTERPRET", "1")
    a = banded_spd(256, 4, seed=6)
    entry = api.get_schedule(a, b_col=16, c_col=16, b_is_sparse=True,
                             cache_size=1e8, ct_size=64)
    assert api.select_backend(entry) == "pallas"
    rng = np.random.default_rng(6)
    c = rng.standard_normal((256, 16))
    got = api.tile_fused_matmul(a, a, jnp.asarray(c, jnp.float32),
                                backend="auto", cache_size=1e8, ct_size=64)
    np.testing.assert_allclose(np.asarray(got),
                               fused_ref.unfused_spmm_spmm(a, a, c),
                               rtol=2e-3, atol=2e-3)
    # without the capability (plain CPU, no forced interpret) auto stays xla
    monkeypatch.delenv("PALLAS_INTERPRET")
    if not api._pallas_capable():
        assert api.select_backend(entry) == "xla"


def test_width_cap_and_autotune_invalidate_cache():
    """Changing the width cap or the autotune flag must miss the schedule
    cache — a capped schedule packs different device arrays, so stale reuse
    would be a silent wrong-layout bug."""
    a = powerlaw_graph(256, 5, seed=7)
    kw = dict(b_col=8, c_col=8, b_is_sparse=True, cache_size=20_000.0)
    e_auto = api.get_schedule(a, **kw)                      # auto cap
    assert api.schedule_cache_stats()["misses"] == 1
    e_pad = api.get_schedule(a, width_cap=None, **kw)       # pad-to-max
    assert e_pad is not e_auto
    assert api.schedule_cache_stats()["misses"] == 2
    e_int = api.get_schedule(a, width_cap=e_auto.width_cap + 3, **kw)
    assert e_int is not e_auto and e_int is not e_pad
    assert api.schedule_cache_stats()["misses"] == 3
    # flipping autotune on is a different entry too (its own sweep key)
    e_at = api.get_schedule(a, autotune=True, **kw)
    assert e_at is not e_auto
    # every knob repeated verbatim is a pure hit: no rebuild, misses flat
    misses = api.schedule_cache_stats()["misses"]
    assert api.get_schedule(a, **kw) is e_auto
    assert api.get_schedule(a, width_cap=None, **kw) is e_pad
    assert api.get_schedule(a, autotune=True, **kw) is e_at
    assert api.schedule_cache_stats()["misses"] == misses


def test_eviction_counters_monotonic(monkeypatch):
    """LRU eviction counters only ever grow, across both caches."""
    monkeypatch.setenv(api.CACHE_ENTRIES_ENV, "2")
    a = banded_spd(128, 4, seed=8)
    b = jnp.ones((128, 8), jnp.float32)
    c = jnp.ones((8, 8), jnp.float32)
    last = (0, 0)
    for ct in (16, 32, 64, 128):
        api.get_schedule(a, b_col=8, c_col=8, ct_size=ct)
        api.tile_fused_matmul(banded_spd(128, 4, seed=ct), b, c,
                              backend="unfused", width_cap=ct % 3 or None)
        stats = api.schedule_cache_stats()
        cur = (stats["evictions"], stats["ell_evictions"])
        assert cur >= last
        last = cur
    assert last[0] >= 2 and last[1] >= 2  # the tiny budget really evicted


def test_cost_model_falls_back_to_unfused():
    """Dense pattern + tiles far smaller than the row span: nothing fuses,
    Eq-3 predicts zero traffic saving, dispatch must pick the unfused code."""
    n = 96
    rng = np.random.default_rng(3)
    a = CSR.from_dense(rng.standard_normal((n, n)))
    entry = api.get_schedule(a, b_col=8, c_col=8, ct_size=16, cache_size=1e12)
    assert entry.sched.fused_ratio < api.MIN_FUSED_RATIO
    assert api.select_backend(entry) == "unfused"
    b = rng.standard_normal((n, 8))
    c = rng.standard_normal((8, 8))
    got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                jnp.asarray(c, jnp.float32), backend="auto",
                                ct_size=16, cache_size=1e12)
    np.testing.assert_allclose(np.asarray(got),
                               fused_ref.unfused_gemm_spmm(a, b, c),
                               rtol=2e-3, atol=2e-3)


def test_marginal_traffic_saving_falls_back_to_unfused():
    """A modeled saving inside (0, MIN_TRAFFIC_SAVING] — positive, but too
    small to cover the tile loop's off-model fixed costs — must dispatch
    unfused even though the schedule clears the fused-ratio floor (the
    hub-heavy GCN training regime where forced-fused ran ~30% slower)."""
    a = powerlaw_graph(256, 8, seed=11)
    entry = api.get_schedule(a, b_col=64, c_col=64, cache_size=100_000.0)
    assert entry.sched.fused_ratio >= api.MIN_FUSED_RATIO
    assert 0.0 < entry.traffic_model["traffic_saving"] \
        <= api.MIN_TRAFFIC_SAVING
    assert api.select_backend(entry) == "unfused"


def test_auto_selects_fused_on_friendly_pattern():
    a = banded_spd(512, 4, seed=5)
    entry = api.get_schedule(a, b_col=32, c_col=32, cache_size=100_000.0,
                             ct_size=128)
    assert api.select_backend(entry) in ("xla", "pallas")


def test_autotune_never_worse_than_default():
    """Acceptance: the Eq-3 sweep may never pick a schedule predicting more
    fast-memory traffic than the paper's ct_size=2048 heuristic."""
    mats = [banded_spd(2048, 6, seed=10), powerlaw_graph(2048, 8, seed=9),
            powerlaw_graph(1024, 4, seed=11)]
    for a in mats:
        api.clear_schedule_cache()
        e_def = api.get_schedule(a, b_col=32, c_col=32,
                                 ct_size=api.DEFAULT_CT_SIZE)
        e_at = api.get_schedule(a, b_col=32, c_col=32, autotune=True)
        assert e_at.traffic_model["fused_bytes"] \
            <= e_def.traffic_model["fused_bytes"]
        assert e_at.autotuned is not None
        e_at.sched.validate()


def test_autotune_sweep_memoized():
    a = banded_spd(512, 4, seed=12)
    e1 = api.get_schedule(a, b_col=16, c_col=16, autotune=True)
    sweeps = api.schedule_cache_stats()["autotune_sweeps"]
    assert sweeps == 1
    e2 = api.get_schedule(a, b_col=16, c_col=16, autotune=True)
    assert e2 is e1                       # the sweep ran exactly once
    assert api.schedule_cache_stats()["autotune_sweeps"] == 1


def test_autotune_matmul_matches_reference():
    a = powerlaw_graph(512, 6, seed=13)
    rng = np.random.default_rng(13)
    b = rng.standard_normal((512, 16))
    c = rng.standard_normal((16, 8))
    want = fused_ref.unfused_gemm_spmm(a, b, c)
    for backend in ("auto", "xla"):
        got = api.tile_fused_matmul(a, jnp.asarray(b, jnp.float32),
                                    jnp.asarray(c, jnp.float32),
                                    backend=backend, autotune=True)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3,
                                   atol=2e-3, err_msg=backend)


def test_cache_lru_eviction(monkeypatch):
    monkeypatch.setenv(api.CACHE_ENTRIES_ENV, "2")
    a = banded_spd(256, 4, seed=0)
    for ct in (32, 64, 128):              # three distinct keys, budget two
        api.get_schedule(a, b_col=8, c_col=8, ct_size=ct)
    stats = api.schedule_cache_stats()
    assert stats["entries"] == 2 and stats["evictions"] == 1
    # the evicted (oldest) key re-inspects; the fresh ones still hit
    api.get_schedule(a, b_col=8, c_col=8, ct_size=128)
    assert api.schedule_cache_stats()["hits"] == 1
    api.get_schedule(a, b_col=8, c_col=8, ct_size=32)
    assert api.schedule_cache_stats()["misses"] == 4


def test_ell_cache_reported_and_bounded(monkeypatch):
    monkeypatch.setenv(api.CACHE_ENTRIES_ENV, "1")
    b = jnp.ones((128, 8), jnp.float32)
    c = jnp.ones((8, 8), jnp.float32)
    for seed in (0, 1):
        api.tile_fused_matmul(banded_spd(128, 4, seed=seed), b, c,
                              backend="unfused")
    stats = api.schedule_cache_stats()
    assert stats["ell_entries"] == 1      # bounded and visible
    assert stats["ell_evictions"] >= 1


def test_invalid_backend_rejected():
    a = banded_spd(64, 2, seed=4)
    with pytest.raises(ValueError):
        api.tile_fused_matmul(a, jnp.ones((64, 4)), jnp.ones((4, 4)),
                              backend="mkl")


# ---------------------------------------------------------------------------
# FusionSpec consolidation: spec= is the cache key, legacy kwargs are a shim
# ---------------------------------------------------------------------------

def test_spec_and_legacy_kwargs_cut_the_same_cache_key():
    """Acceptance: a FusionSpec and the equivalent legacy keywords resolve
    to the SAME schedule-cache entry — the spec really is the key, not a
    parallel surface that could drift."""
    a = banded_spd(256, 4, seed=20)
    spec = api.FusionSpec(p=2, cache_size=30_000.0, ct_size=32)
    e_spec = api.get_schedule(a, b_col=8, c_col=8, spec=spec)
    assert api.schedule_cache_stats()["misses"] == 1
    with pytest.warns(DeprecationWarning):
        e_legacy = api.get_schedule(a, b_col=8, c_col=8, p=2,
                                    cache_size=30_000.0, ct_size=32)
    assert e_legacy is e_spec             # pure hit, no rebuild
    stats = api.schedule_cache_stats()
    assert (stats["hits"], stats["misses"]) == (1, 1)
    assert stats["spec_entries"] == 1
    # a field change is a different resolved spec and a fresh entry
    e2 = api.get_schedule(a, b_col=8, c_col=8,
                          spec=dataclasses.replace(spec, ct_size=64))
    assert e2 is not e_spec
    assert api.schedule_cache_stats()["spec_entries"] == 2


def test_legacy_kwargs_warn_once_per_process():
    """The deprecation shim is structured (DeprecationWarning) and fires
    exactly once per process; clear_schedule_cache re-arms it so tests
    stay order-independent."""
    a = banded_spd(128, 4, seed=21)
    with pytest.warns(DeprecationWarning, match="FusionSpec"):
        api.get_schedule(a, b_col=8, c_col=8, p=2)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        api.get_schedule(a, b_col=8, c_col=8, p=4)   # second call: silent
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]
    api.clear_schedule_cache()                       # re-arms the warning
    with pytest.warns(DeprecationWarning):
        api.get_schedule(a, b_col=8, c_col=8, p=2)


def test_mixing_spec_and_legacy_kwargs_rejected():
    a = banded_spd(64, 2, seed=22)
    with pytest.raises(TypeError, match="both spec="):
        api.get_schedule(a, b_col=4, c_col=4,
                         spec=api.FusionSpec(), ct_size=32)
    with pytest.raises(TypeError, match="unexpected keyword"):
        api.get_schedule(a, b_col=4, c_col=4, ct_sizee=32)  # typo knob
    with pytest.raises(TypeError, match="FusionSpec"):
        api.get_schedule(a, b_col=4, c_col=4, spec={"p": 2})


def test_spec_validates_overlap_and_n_repl():
    with pytest.raises(ValueError, match="overlap"):
        api.FusionSpec(overlap="yes")
    with pytest.raises(ValueError, match="n_repl"):
        api.FusionSpec(n_repl=0)
    # inert distribution knobs collapse on a trivial mesh: mesh=None specs
    # share one entry regardless of overlap/n_repl values
    a = banded_spd(128, 4, seed=23)
    e1 = api.get_schedule(a, b_col=8, c_col=8,
                          spec=api.FusionSpec(overlap=True, n_repl=2))
    e2 = api.get_schedule(a, b_col=8, c_col=8,
                          spec=api.FusionSpec(overlap=False, n_repl=None))
    assert e2 is e1
    assert api.schedule_cache_stats()["spec_entries"] == 1
