"""End-to-end behaviour: the paper's claims as executable assertions."""
from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import build_schedule, to_device_schedule


def test_claim_two_wavefronts_no_redundancy():
    """Paper conclusion: 'The created schedule does not use redundant
    computation and its synchronizations are always 2.'"""
    for seed, gen in enumerate((banded_spd, powerlaw_graph)):
        a = gen(512, 8, seed=seed)
        s = build_schedule(a, b_col=32, c_col=32, p=4,
                           cache_size=100_000.0, ct_size=128)
        assert len(s.wavefronts) == 2
        # no redundancy: every I iteration appears exactly once (validate()
        # checks this); overlapped tiling would replicate
        s.validate()


def test_claim_spd_fuses_better_than_graphs():
    """Paper §4.2.1: 'fused ratio in SPD matrices is on average 2x higher
    than graph matrices.'"""
    spd = banded_spd(2048, 8, seed=0)
    graph = powerlaw_graph(2048, 8, seed=0)
    kw = dict(b_col=64, c_col=64, p=8, cache_size=1e12, ct_size=512)
    r_spd = build_schedule(spd, **kw).fused_ratio
    r_graph = build_schedule(graph, **kw).fused_ratio
    assert r_spd > r_graph


def test_claim_traffic_saving_grows_with_fused_ratio():
    """The locality mechanism: more fused iterations -> less D1 spill."""
    a = banded_spd(1024, 4, seed=1)
    kw = dict(b_col=32, c_col=32, p=4, cache_size=1e12)
    savings = []
    for ct in (16, 128, 1024):
        s = build_schedule(a, ct_size=ct, **kw)
        ds = to_device_schedule(a, s)
        savings.append(ds.hbm_traffic_model(32, 32)["traffic_saving"])
    assert savings[-1] >= savings[0]


def test_scheduler_is_linear_ish():
    """Complexity claim: scheduler is O(nnz log ct) — must handle a 50k-row
    matrix in seconds."""
    import time
    a = banded_spd(50_000, 8, seed=2)
    t0 = time.time()
    s = build_schedule(a, b_col=64, c_col=64, p=16, cache_size=600_000.0,
                       ct_size=2048)
    assert time.time() - t0 < 30.0
    s.validate()
