"""Attention paths: chunked-XLA flash vs naive, ring-buffer decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.models.layers import chunked_attention, decode_attention

RNG = np.random.default_rng(0)


def arr(shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 16)])
@pytest.mark.parametrize("sq,sk,chunk", [(64, 64, 16), (8, 120, 32)])
def test_chunked_attention_vs_naive(causal, window, sq, sk, chunk):
    if causal and sq != sk:
        pytest.skip("causal offsets tested separately")
    q = arr((2, 4, sq, 16))
    k = arr((2, 2, sk, 16))   # GQA 2:1
    v = arr((2, 2, sk, 16))
    got = chunked_attention(q, k, v, causal=causal, window=window,
                            chunk=chunk)
    k_r = jnp.repeat(k, 2, axis=1)
    v_r = jnp.repeat(v, 2, axis=1)
    want = kref.attention(q, k_r, v_r, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_q_offset():
    """Decode continuation: 1 query at position 10 of a 16-long kv."""
    q = arr((1, 2, 1, 8))
    k = arr((1, 2, 16, 8))
    v = arr((1, 2, 16, 8))
    got = chunked_attention(q, k, v, causal=True, chunk=4, q_offset=10)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (8 ** 0.5)
    mask = jnp.arange(16)[None, None, None, :] <= 10
    want = jax.nn.softmax(jnp.where(mask, s, -1e30), -1) @ v
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_validity_mask():
    q = arr((1, 2, 1, 8))
    k_cache = arr((1, 2, 16, 8))
    v_cache = arr((1, 2, 16, 8))
    n_valid = 5
    got = decode_attention(q, k_cache, v_cache, jnp.int32(n_valid))
    want = decode_attention(q, k_cache[:, :, :n_valid],
                            v_cache[:, :, :n_valid], jnp.int32(n_valid))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ring_buffer_equals_full_window_attention():
    """Sliding-window decode with a ring buffer == full cache + window mask."""
    from repro.configs import get_config
    from repro.models import transformer as T
    cfg = get_config("hymba-1.5b", reduced=True)   # window=32 reduced
    assert cfg.window == 32
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    seq = 48   # exceeds the window -> ring wraps
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, seq), 0,
                              cfg.vocab_size)
    full = T.forward(cfg, params, {"tokens": toks}).astype(jnp.float32)
    cache = T.init_cache(cfg, 1, seq)
    outs = []
    for i in range(seq):
        lg, cache = T.decode_step(cfg, params, {"tokens": toks[:, i:i + 1]},
                                  cache, jnp.int32(i))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=0.1, atol=0.15)
