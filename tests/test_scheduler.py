"""Scheduler (Algorithm 1) unit + property tests."""
import dataclasses

import numpy as np
from _prop import given, settings, st

from repro.core.sparse.formats import CSR, TileELL
from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import (build_schedule, fused_compute_ratio,
                                   reference, tile_cost_elements,
                                   to_device_schedule)
from repro.core.tilefusion.cost_model import tile_costs_batch


def random_csr(n, density, seed):
    rng = np.random.default_rng(seed)
    m = int(density * n * n)
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    vals = rng.standard_normal(m)
    return CSR.from_coo(n, n, rows, cols, vals)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 200), density=st.floats(0.001, 0.1),
       seed=st.integers(0, 10), ct=st.sampled_from([8, 16, 64, 2048]),
       p=st.integers(1, 8), uniform=st.booleans())
def test_schedule_invariants(n, density, seed, ct, p, uniform):
    a = random_csr(n, density, seed)
    sched = build_schedule(a, b_col=16, c_col=16, p=p, cache_size=5_000.0,
                           ct_size=ct, uniform_split=uniform)
    sched.validate()  # I covered exactly once; J covered exactly once
    assert len(sched.wavefronts) == 2          # paper: exactly 1 barrier
    assert 0.0 <= sched.fused_ratio <= 1.0
    # the defining fusion property: every fused row's deps are inside its tile
    for tl in sched.wavefronts[0]:
        for j in tl.j_rows:
            cols = a.indices[a.indptr[j]:a.indptr[j + 1]]
            assert ((cols >= tl.i_start) & (cols < tl.i_end)).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 5))
def test_step2_respects_cache(seed):
    a = random_csr(256, 0.02, seed)
    cache = 3_000.0
    sched = build_schedule(a, b_col=16, c_col=16, p=4, cache_size=cache,
                           ct_size=64)
    for w, wf in enumerate(sched.wavefronts):
        for tl in wf:
            cost = tile_cost_elements(a, tl.i_start, tl.i_end, tl.j_rows,
                                      16, 16, False)
            # splitting bottoms out at 1-row tiles; only those may exceed
            if tl.n_i > 1 or (tl.n_i == 0 and tl.n_j > 1):
                assert cost <= cache, (w, tl.i_start, tl.i_end, cost)


def test_uniform_split_is_uniform():
    a = banded_spd(512, 8, seed=0)
    sched = build_schedule(a, b_col=64, c_col=64, p=4, cache_size=50_000.0,
                           ct_size=256, uniform_split=True)
    sizes = {tl.n_i for tl in sched.wavefronts[0]}
    assert len(sizes - {sched.t}) <= 1  # last tile may be short


def test_fused_ratio_monotone_in_tile_size():
    """Paper Fig 4: fused ratio is non-decreasing in coarse tile size."""
    a = powerlaw_graph(1024, 8, seed=3)
    ratios = []
    for ct in (32, 128, 512, 1024):
        s = build_schedule(a, b_col=8, c_col=8, p=1, cache_size=1e12,
                           ct_size=ct)
        ratios.append(s.fused_ratio)
    assert all(b >= a_ - 1e-9 for a_, b in zip(ratios, ratios[1:])), ratios


def test_load_balance_constraint():
    """|T_w| >= p when there is enough work (paper's constraint)."""
    a = banded_spd(2048, 4, seed=1)
    for p in (2, 4, 8):
        s = build_schedule(a, b_col=8, c_col=8, p=p, cache_size=1e12,
                           ct_size=2048)
        assert len(s.wavefronts[0]) >= p


def test_fig1_ratio_bounds():
    a = powerlaw_graph(512, 8, seed=2)
    r = fused_compute_ratio(a, ct_size=128)
    assert 0.0 <= r <= 1.0


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 220), density=st.floats(0.001, 0.1),
       seed=st.integers(0, 10), ct=st.sampled_from([8, 64, 2048]),
       p=st.integers(1, 8), uniform=st.booleans(),
       cache=st.sampled_from([2_000.0, 1e12]), bsp=st.booleans())
def test_vectorized_scheduler_matches_loop_reference(n, density, seed, ct, p,
                                                     uniform, cache, bsp):
    """The O(nnz) vectorized inspector must be *identical* to the retained
    loop-based reference — same tiles in the same order, same device
    arrays — on random CSR patterns across every knob."""
    a = random_csr(n, density, seed)
    kw = dict(b_col=16, c_col=16, p=p, cache_size=cache, ct_size=ct,
              b_is_sparse=bsp, uniform_split=uniform)
    got = build_schedule(a, **kw)
    want = reference.build_schedule_ref(a, **kw)
    assert (got.t, got.n_i, got.n_j) == (want.t, want.n_i, want.n_j)
    for wf_got, wf_want in zip(got.wavefronts, want.wavefronts):
        assert len(wf_got) == len(wf_want)
        for tg, tw in zip(wf_got, wf_want):
            assert (tg.i_start, tg.i_end) == (tw.i_start, tw.i_end)
            assert np.array_equal(tg.j_rows, tw.j_rows)
    ds_got = to_device_schedule(a, got)
    ds_want = reference.to_device_schedule_ref(a, want)
    for f in dataclasses.fields(ds_got):
        g, w = getattr(ds_got, f.name), getattr(ds_want, f.name)
        if isinstance(g, np.ndarray):
            assert g.shape == w.shape and np.array_equal(g, w), f.name
        else:
            assert g == w, f.name


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 200), density=st.floats(0.005, 0.1),
       seed=st.integers(0, 6), bsp=st.booleans())
def test_batched_cost_matches_scalar(n, density, seed, bsp):
    """tile_costs_batch is element-for-element tile_cost_elements."""
    a = random_csr(n, density, seed)
    rng = np.random.default_rng(seed)
    tiles = []
    for i0 in range(0, n, 32):
        k = int(rng.integers(0, min(n, 24)))
        jr = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int32)
        tiles.append((i0, min(i0 + 32, n), jr))
    batch = tile_costs_batch(a, [t[0] for t in tiles], [t[1] for t in tiles],
                             [t[2] for t in tiles], 16, 8, bsp)
    for cost, (i0, i1, jr) in zip(batch, tiles):
        assert cost == tile_cost_elements(a, i0, i1, jr, 16, 8, bsp)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(8, 150), density=st.floats(0.005, 0.1),
       seed=st.integers(0, 5), ct=st.sampled_from([16, 128]))
def test_vectorized_packers_match_loop_reference(n, density, seed, ct):
    a = random_csr(n, density, seed)
    r = fused_compute_ratio(a, ct_size=ct)
    assert abs(r - reference.fused_compute_ratio_ref(a, ct_size=ct)) < 1e-12
    rows = np.arange(a.n_rows, dtype=np.int64)
    got = TileELL.from_csr_rows(a, rows)
    want = reference.tile_ell_from_csr_rows_ref(a, rows)
    assert np.array_equal(got.cols, want.cols)
    assert np.array_equal(got.vals, want.vals)
    # explicit (truncating) width
    sub = rows[:: max(n // 7, 1)]
    got = TileELL.from_csr_rows(a, sub, width=2)
    want = reference.tile_ell_from_csr_rows_ref(a, sub, width=2)
    assert np.array_equal(got.cols, want.cols)
    assert np.array_equal(got.vals, want.vals)


def test_empty_and_rectangular_patterns_match_reference():
    """Degenerate shapes the vectorized index arithmetic must not trip on."""
    rng = np.random.default_rng(0)
    mats = [CSR.from_dense(np.zeros((6, 6))),
            CSR.from_coo(120, 60, rng.integers(0, 120, 200),
                         rng.integers(0, 60, 200), rng.standard_normal(200)),
            CSR.from_coo(60, 120, rng.integers(0, 60, 200),
                         rng.integers(0, 120, 200), rng.standard_normal(200))]
    for a in mats:
        kw = dict(b_col=8, c_col=8, p=2, cache_size=2_000.0, ct_size=16)
        got = build_schedule(a, **kw)
        want = reference.build_schedule_ref(a, **kw)
        for wf_got, wf_want in zip(got.wavefronts, want.wavefronts):
            assert len(wf_got) == len(wf_want)
            for tg, tw in zip(wf_got, wf_want):
                assert np.array_equal(tg.j_rows, tw.j_rows)


def test_device_schedule_roundtrip():
    a = powerlaw_graph(300, 6, seed=4)
    sched = build_schedule(a, b_col=8, c_col=8, p=4, cache_size=20_000.0,
                           ct_size=64)
    ds = to_device_schedule(a, sched)
    assert ds.n_i == 300 and ds.n_j == 300
    # every real (non-pad) wavefront-0 ELL column is tile-local
    for v in range(ds.n_tiles0):
        real = ds.ell_vals0[v] != 0
        if real.any():
            assert ds.ell_cols0[v][real].min() >= 0
            assert ds.ell_cols0[v][real].max() < ds.t_pad
    tm = ds.hbm_traffic_model(8, 8)
    assert 0.0 <= tm["traffic_saving"] <= 1.0
    assert tm["fused_bytes"] <= tm["unfused_bytes"]
