"""Scheduler (Algorithm 1) unit + property tests."""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.sparse.formats import CSR
from repro.core.sparse.random import banded_spd, powerlaw_graph
from repro.core.tilefusion import (build_schedule, fused_compute_ratio,
                                   tile_cost_elements, to_device_schedule)


def random_csr(n, density, seed):
    rng = np.random.default_rng(seed)
    m = int(density * n * n)
    rows = rng.integers(0, n, m)
    cols = rng.integers(0, n, m)
    vals = rng.standard_normal(m)
    return CSR.from_coo(n, n, rows, cols, vals)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(8, 200), density=st.floats(0.001, 0.1),
       seed=st.integers(0, 10), ct=st.sampled_from([8, 16, 64, 2048]),
       p=st.integers(1, 8), uniform=st.booleans())
def test_schedule_invariants(n, density, seed, ct, p, uniform):
    a = random_csr(n, density, seed)
    sched = build_schedule(a, b_col=16, c_col=16, p=p, cache_size=5_000.0,
                           ct_size=ct, uniform_split=uniform)
    sched.validate()  # I covered exactly once; J covered exactly once
    assert len(sched.wavefronts) == 2          # paper: exactly 1 barrier
    assert 0.0 <= sched.fused_ratio <= 1.0
    # the defining fusion property: every fused row's deps are inside its tile
    for tl in sched.wavefronts[0]:
        for j in tl.j_rows:
            cols = a.indices[a.indptr[j]:a.indptr[j + 1]]
            assert ((cols >= tl.i_start) & (cols < tl.i_end)).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 5))
def test_step2_respects_cache(seed):
    a = random_csr(256, 0.02, seed)
    cache = 3_000.0
    sched = build_schedule(a, b_col=16, c_col=16, p=4, cache_size=cache,
                           ct_size=64)
    for w, wf in enumerate(sched.wavefronts):
        for tl in wf:
            cost = tile_cost_elements(a, tl.i_start, tl.i_end, tl.j_rows,
                                      16, 16, False)
            # splitting bottoms out at 1-row tiles; only those may exceed
            if tl.n_i > 1 or (tl.n_i == 0 and tl.n_j > 1):
                assert cost <= cache, (w, tl.i_start, tl.i_end, cost)


def test_uniform_split_is_uniform():
    a = banded_spd(512, 8, seed=0)
    sched = build_schedule(a, b_col=64, c_col=64, p=4, cache_size=50_000.0,
                           ct_size=256, uniform_split=True)
    sizes = {tl.n_i for tl in sched.wavefronts[0]}
    assert len(sizes - {sched.t}) <= 1  # last tile may be short


def test_fused_ratio_monotone_in_tile_size():
    """Paper Fig 4: fused ratio is non-decreasing in coarse tile size."""
    a = powerlaw_graph(1024, 8, seed=3)
    ratios = []
    for ct in (32, 128, 512, 1024):
        s = build_schedule(a, b_col=8, c_col=8, p=1, cache_size=1e12,
                           ct_size=ct)
        ratios.append(s.fused_ratio)
    assert all(b >= a_ - 1e-9 for a_, b in zip(ratios, ratios[1:])), ratios


def test_load_balance_constraint():
    """|T_w| >= p when there is enough work (paper's constraint)."""
    a = banded_spd(2048, 4, seed=1)
    for p in (2, 4, 8):
        s = build_schedule(a, b_col=8, c_col=8, p=p, cache_size=1e12,
                           ct_size=2048)
        assert len(s.wavefronts[0]) >= p


def test_fig1_ratio_bounds():
    a = powerlaw_graph(512, 8, seed=2)
    r = fused_compute_ratio(a, ct_size=128)
    assert 0.0 <= r <= 1.0


def test_device_schedule_roundtrip():
    a = powerlaw_graph(300, 6, seed=4)
    sched = build_schedule(a, b_col=8, c_col=8, p=4, cache_size=20_000.0,
                           ct_size=64)
    ds = to_device_schedule(a, sched)
    assert ds.n_i == 300 and ds.n_j == 300
    # every real (non-pad) wavefront-0 ELL column is tile-local
    for v in range(ds.n_tiles0):
        real = ds.ell_vals0[v] != 0
        if real.any():
            assert ds.ell_cols0[v][real].min() >= 0
            assert ds.ell_cols0[v][real].max() < ds.t_pad
    tm = ds.hbm_traffic_model(8, 8)
    assert 0.0 <= tm["traffic_saving"] <= 1.0
    assert tm["fused_bytes"] <= tm["unfused_bytes"]
